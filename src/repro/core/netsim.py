"""Event-driven testbed simulator reproducing the paper's evaluation setup.

The paper measures on 10 physical devices behind 3 routers (Fig. 3): every
transfer traverses the sender's access link, the inter-router trunk when the
endpoints live in different subnets, and the receiver's access link.
Concurrent transfers *share* link capacity — which is precisely why naive
flooding collapses: every node transmitting to every neighbour at once
divides each link's bandwidth by the number of simultaneous flows, while the
MST+coloring schedule keeps concurrency (and hence contention) low.

We reproduce that mechanism with a deterministic fluid-flow simulation:
at any instant each flow's rate is ``min`` over its traversed links of the
link's fair share (capacity / flows on link); the simulation advances to the
next flow completion, re-solving rates each time.

Protocols are *not* implemented here: :func:`simulate_policy` is a thin
interpreter of the communication-plan IR (:mod:`repro.core.plan`). Slot
policies run with a drain barrier between slots (the paper's self-clocked
slots); event policies (flooding) launch new flows the instant a delivery
completes.

Metrics match the paper's three tables:
  * bandwidth (MB/s): mean per-transfer achieved rate         (Table III)
  * single transfer time (s): mean flow duration              (Table IV)
  * total round time (s): wall time for full dissemination    (Table V)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .graph import Graph, TopologySpec, build_mst, color_graph, subnet_of
from .network import (  # noqa: F401  (LinkId re-exported: historical home)
    CompiledNetwork,
    LinkId,
    NetworkSpec,
    as_network_model,
    mask_underlay,
)
from .plan import (
    BroadcastOncePolicy,
    CommPolicy,
    DisseminationPolicy,
    FloodingPolicy,
    MstExchangePolicy,
    ReplayPolicy,
    Send,
    SlotPlan,
)


@dataclass
class TestbedSpec:
    """Physical underlay: N devices across `n_subnets` routers.

    Since the network-model API (:mod:`repro.core.network`) this is a
    back-compat wrapper over the default paper network — 3 subnets behind a
    full router mesh, uniform access rates. Routing (:meth:`links_for`) and
    latency (:meth:`latency`) delegate to the compiled network model built
    from :meth:`to_network`, so hop counts and trunk traversals are derived
    from the actual routing path rather than assumed; for the full-mesh
    default the results are byte-identical to the historical hardcoded
    0-or-2-hop rule (pinned by ``tests/test_network.py``).
    """

    n: int = 10
    n_subnets: int = 3
    access_mbps: float = 12.0  # device<->router capacity, MB/s
    trunk_mbps: float = 30.0  # router<->router capacity, MB/s
    base_latency_s: float = 0.15  # per-transfer protocol overhead (FTP setup)
    hop_latency_s: float = 0.35  # extra latency per router hop
    per_flow_cap_mbps: float = 11.0  # single-flow application ceiling (FTP/disk)
    # Goodput collapse under contention (paper I: packet loss -> retransmission
    # -> queuing delays): with k flows on a link, usable capacity shrinks by
    # 1/(1 + collapse_gamma * max(0, k - collapse_k0)).
    collapse_gamma: float = 0.05
    collapse_k0: int = 3
    # Collapse compounds over sustained congestion episodes; longer transfers
    # (bigger models) suffer more loss/retransmission, so the effective gamma
    # scales with sqrt(model_size / collapse_ref_mb) (paper Table III trend).
    collapse_ref_mb: float = 30.0
    # Churn masking (scenario runner): when the healthy membership is a
    # subset of the physical testbed, ``node_ids[i]`` is the physical node id
    # of dense index i and ``phys_n`` the physical device count, so subnet
    # routing follows the *physical* layout rather than the dense reindexing.
    node_ids: Optional[Tuple[int, ...]] = None
    phys_n: Optional[int] = None

    @classmethod
    def from_overlay(cls, overlay: TopologySpec, **overrides) -> "TestbedSpec":
        """Derive the physical underlay from the overlay's subnet/cost model.

        ``n`` and ``n_subnets`` are taken from the :class:`TopologySpec`, so
        the routing (:meth:`subnet`, via the shared
        :func:`repro.core.graph.subnet_of`) and the overlay's edge costs are
        two views of one subnet layout. Latencies are scaled from the
        overlay's ping ranges relative to the paper testbed's defaults
        (intra-subnet midpoint 0.95 ms ~ 0.15 s FTP setup; inter-subnet
        midpoint 24 ms ~ 0.35 s per router hop), so the default overlay spec
        reproduces the paper's underlay exactly while a slower overlay yields
        a proportionally slower underlay.
        """
        intra_mid = (overlay.intra_cost_ms[0] + overlay.intra_cost_ms[1]) / 2.0
        inter_mid = (overlay.inter_cost_ms[0] + overlay.inter_cost_ms[1]) / 2.0
        derived = dict(
            n=overlay.n,
            n_subnets=overlay.n_subnets,
            base_latency_s=0.15 * (intra_mid / 0.95),
            hop_latency_s=0.35 * (inter_mid / 24.0),
        )
        derived.update(overrides)
        return cls(**derived)

    def subnet(self, node: int) -> int:
        if self.node_ids is not None:
            return subnet_of(self.node_ids[node], self.phys_n or self.n,
                             self.n_subnets)
        return subnet_of(node, self.n, self.n_subnets)

    def masked(self, members) -> "TestbedSpec":
        """The testbed restricted to ``members`` — the shared
        :func:`repro.core.network.mask_underlay` rule."""
        return mask_underlay(self, members)

    def to_network(self) -> NetworkSpec:
        """This testbed as a declarative :class:`NetworkSpec` (mesh fabric)."""
        return NetworkSpec(
            name="testbed", n=self.n, n_subnets=self.n_subnets,
            router_kind="mesh", access_mbps=self.access_mbps,
            trunk_mbps=self.trunk_mbps, base_latency_s=self.base_latency_s,
            hop_latency_s=self.hop_latency_s,
            per_flow_cap_mbps=self.per_flow_cap_mbps,
            collapse_gamma=self.collapse_gamma, collapse_k0=self.collapse_k0,
            collapse_ref_mb=self.collapse_ref_mb,
            node_ids=self.node_ids, phys_n=self.phys_n)

    def _compiled(self) -> CompiledNetwork:
        """Lazily compiled routing view (rebuilt if routing fields change)."""
        key = (self.n, self.n_subnets, self.access_mbps, self.trunk_mbps,
               self.base_latency_s, self.hop_latency_s,
               self.node_ids, self.phys_n)
        cached = self.__dict__.get("_net")
        if cached is None or cached[0] != key:
            cached = (key, self.to_network().build())
            self.__dict__["_net"] = cached
        return cached[1]

    def links_for(self, src: int, dst: int) -> List[LinkId]:
        return self._compiled().links_for(src, dst)

    def capacity(self, link: LinkId) -> float:
        return self._compiled().capacity(link)

    def latency(self, src: int, dst: int) -> float:
        return self._compiled().latency(src, dst)


@dataclass
class _Flow:
    src: int
    dst: int
    owner: int
    size_mb: float
    remaining_mb: float
    links: List[LinkId]
    start: float
    latency_left: float  # setup latency before bytes move
    done_at: Optional[float] = None


@dataclass
class SimResult:
    total_time_s: float
    mean_transfer_s: float
    mean_bandwidth_mbps: float
    n_transfers: int
    max_concurrency: int
    # Exact bytes that crossed links, MB: the sum of per-flow wire sizes
    # (codec-encoded when simulate_policy ran with a payload codec).
    bytes_on_wire_mb: float = 0.0
    per_transfer_s: List[float] = field(default_factory=list)
    # Optional launch trace for cross-executor equivalence tests:
    # send_trace[t] = the (src, dst, payload) flows launched in batch t
    # (one batch per slot for slot policies; per trigger for event policies).
    send_trace: Optional[List[List[Send]]] = None


class FluidSimulator:
    """Max-min-ish fair-share fluid flow simulator over the network links.

    ``spec`` is any *network model* (:class:`TestbedSpec`,
    :class:`repro.core.network.CompiledNetwork`): the simulator only ever
    calls ``links_for`` / ``capacity`` / ``latency`` and reads the
    contention constants, so every underlay shape the network API can
    declare runs here unchanged.
    """

    def __init__(self, spec: Union[TestbedSpec, CompiledNetwork],
                 congestion_scale: float = 1.0) -> None:
        self.spec = spec
        self.congestion_scale = congestion_scale
        self.t = 0.0
        self.flows: List[_Flow] = []
        self.finished: List[_Flow] = []
        self.max_concurrency = 0

    def add_flow(self, src: int, dst: int, owner: int, size_mb: float) -> None:
        self.flows.append(
            _Flow(
                src,
                dst,
                owner,
                size_mb,
                size_mb,
                self.spec.links_for(src, dst),
                self.t,
                self.spec.latency(src, dst),
            )
        )

    def _rates(self) -> Dict[int, float]:
        counts: Dict[LinkId, int] = {}
        for i, f in enumerate(self.flows):
            if f.latency_left > 0:
                continue
            for l in f.links:
                counts[l] = counts.get(l, 0) + 1
        rates = {}
        sp = self.spec
        for i, f in enumerate(self.flows):
            if f.latency_left > 0:
                continue
            gamma = sp.collapse_gamma * self.congestion_scale
            share = min(
                sp.capacity(l)
                / counts[l]
                / (1.0 + gamma * max(0, counts[l] - sp.collapse_k0))
                for l in f.links
            )
            rates[i] = min(share, sp.per_flow_cap_mbps)
        return rates

    def run_until_drained(self, on_complete) -> None:
        """Advance until no flows remain. ``on_complete(flow)`` may add flows."""
        while self.flows:
            self.max_concurrency = max(self.max_concurrency, len(self.flows))
            rates = self._rates()
            # next event: a latency expiry or a flow completion
            dt = np.inf
            for i, f in enumerate(self.flows):
                if f.latency_left > 0:
                    dt = min(dt, f.latency_left)
                else:
                    r = rates[i]
                    if r > 0:
                        dt = min(dt, f.remaining_mb / r)
            if not np.isfinite(dt):
                raise RuntimeError("simulation stalled")
            dt = max(dt, 1e-12)
            self.t += dt
            still: List[_Flow] = []
            completed: List[_Flow] = []
            for i, f in enumerate(self.flows):
                if f.latency_left > 0:
                    f.latency_left = max(0.0, f.latency_left - dt)
                    still.append(f)
                    continue
                f.remaining_mb -= rates[i] * dt
                if f.remaining_mb <= 1e-9:
                    f.done_at = self.t
                    completed.append(f)
                else:
                    still.append(f)
            self.flows = still
            for f in completed:
                self.finished.append(f)
                on_complete(f)


def _collect(sim: FluidSimulator, send_trace: Optional[List[List[Send]]] = None) -> SimResult:
    """Assemble the paper's three metrics from a drained simulator."""
    durations = [f.done_at - f.start for f in sim.finished]
    rates = [f.size_mb / d for f, d in zip(sim.finished, durations)]
    return SimResult(
        total_time_s=sim.t,
        mean_transfer_s=float(np.mean(durations)),
        mean_bandwidth_mbps=float(np.mean(rates)),
        n_transfers=len(durations),
        max_concurrency=sim.max_concurrency,
        bytes_on_wire_mb=float(sum(f.size_mb for f in sim.finished)),
        per_transfer_s=durations,
        send_trace=send_trace,
    )


# ---------------------------------------------------------------------------
# The one protocol driver: interpret a communication policy over the testbed
# ---------------------------------------------------------------------------


def simulate_policy(
    policy: CommPolicy,
    spec: Union[TestbedSpec, NetworkSpec, CompiledNetwork, str],
    model_mb: float,
    record_trace: bool = False,
    max_slots: int = 100_000,
    codec=None,
    span_offset: float = 0.0,
) -> SimResult:
    """Execute a communication policy on the fluid network.

    Slot policies are self-clocked: slot k+1's sends start when slot k's
    transfers complete (the paper's fixed slot length upper-bounds the same
    thing; we report the achieved time, which the fixed slot would round up).
    Event policies launch follow-up flows the instant a delivery completes.
    Each flow carries ``model_mb × policy.payload_fraction`` MB (fractions
    below 1 model segmented gossip), encoded through ``codec`` (a
    :class:`repro.compress.Codec`) when one is given — compressed transfers
    are both smaller and, being shorter-lived, suffer less goodput collapse.

    ``spec`` is any underlay declaration the network API resolves: a
    :class:`TestbedSpec`, a :class:`repro.core.network.NetworkSpec`, a
    compiled model, or a preset name (sized to ``policy.n``).

    When an observability recorder is active (:mod:`repro.obs`), each slot
    becomes a virtual-time span on the ``netsim`` lane (offset by
    ``span_offset``, so a multi-round caller strings its rounds into one
    continuous virtual timeline) carrying the slot's send count and wire
    bytes; disabled recorders cost one attribute check per call.
    """
    from .. import obs
    from ..compress import per_send_wire_mb  # numpy-only, no cycle

    spec = as_network_model(spec, n=policy.n)
    size_mb = per_send_wire_mb(codec, model_mb, policy.payload_fraction)
    sim = FluidSimulator(spec, (size_mb / spec.collapse_ref_mb) ** 0.5)
    trace: Optional[List[List[Send]]] = [] if record_trace else None
    policy.reset()
    rec = obs.get()

    def launch(sends: Sequence[Send]) -> None:
        if trace is not None:
            trace.append(list(sends))
        for src, dst, payload in sends:
            sim.add_flow(src, dst, payload, size_mb)

    if policy.sync == "event":
        launch(policy.initial_sends())

        def on_complete(f: _Flow) -> None:
            launch(policy.on_delivered(f.src, f.dst, f.owner))

        sim.run_until_drained(on_complete)
        if rec.enabled:
            rec.add_span(f"{policy.kind} (event)", span_offset,
                         span_offset + sim.t, track="netsim", cat="netsim",
                         args={"transfers": len(sim.finished)})
    else:
        t = 0
        while not policy.done():
            if t >= max_slots:
                raise RuntimeError(f"{policy.kind} did not converge")
            sends = policy.emit(t)
            tup = sends.tuples()
            launch(tup)
            policy.commit(t, sends)
            t0 = sim.t
            sim.run_until_drained(lambda f: None)
            if rec.enabled:
                rec.add_span(f"slot {t}", span_offset + t0,
                             span_offset + sim.t, track="netsim",
                             cat="netsim-slot",
                             args={"sends": len(tup),
                                   "wire_mb": len(tup) * size_mb})
                rec.count("netsim.slot_wire_mb", len(tup) * size_mb)
            t += 1
    return _collect(sim, trace)


# ---------------------------------------------------------------------------
# Back-compat wrappers (each is now one policy + the shared driver)
# ---------------------------------------------------------------------------


def simulate_flooding(
    overlay: Graph, spec: TestbedSpec, model_mb: float
) -> SimResult:
    """Uncoordinated flooding: forward every new model to every neighbour
    immediately on receipt. All of a node's sends contend on its access link.
    """
    return simulate_policy(FloodingPolicy(overlay), spec, model_mb)


def simulate_mosgu(
    overlay: Graph,
    spec: TestbedSpec,
    model_mb: float,
    plan: Optional[SlotPlan] = None,
    mst_algorithm: str = "prim",
    coloring_algorithm: str = "bfs",
) -> SimResult:
    """Slot-scheduled gossip on the colored MST (live policy, or a compiled
    plan replayed through :class:`repro.core.plan.ReplayPolicy`)."""
    if plan is not None:
        return simulate_policy(ReplayPolicy(plan), spec, model_mb)
    mst = build_mst(overlay, mst_algorithm)
    colors = color_graph(mst, coloring_algorithm)
    return simulate_policy(DisseminationPolicy(mst, colors), spec, model_mb)


def simulate_broadcast_exchange(spec: TestbedSpec, model_mb: float) -> SimResult:
    """The paper's broadcast baseline for one FL communication round.

    The *overlay* is complete (paper IV-B: every node connects to every other
    node), so conventional broadcasting means all N nodes push their local
    model to the other N-1 concurrently — N·(N-1) flows contending on every
    access link and the trunks. This is why the paper's broadcast columns are
    identical across underlay topologies (merged cells in Tables III–V).
    """
    return simulate_policy(BroadcastOncePolicy(spec.n), spec, model_mb)


def simulate_mosgu_exchange(
    topology_graph: Graph, spec: TestbedSpec, model_mb: float
) -> SimResult:
    """One MOSGU exchange step: two colored slots on the MST.

    Each node multicasts its *own* current model to its MST neighbours during
    its color's slot (slot 0 = color 0 senders, slot 1 = color 1), matching
    the paper's per-round measurement unit. Full dissemination (Table I) is
    simulated by :func:`simulate_mosgu`.
    """
    mst = build_mst(topology_graph)
    colors = color_graph(mst)
    return simulate_policy(MstExchangePolicy(mst, colors), spec, model_mb)


def compare_protocols(
    topology: str,
    model_mb: float,
    n: int = 10,
    seed: int = 0,
    spec: Optional[TestbedSpec] = None,
    full_dissemination: bool = False,
    protocols: Optional[Sequence[str]] = None,
    n_segments: int = 4,
) -> Dict[str, SimResult]:
    """Run protocols on one (topology, model size); the benchmark unit.

    Deprecated front door: this now delegates to the declarative scenario
    API (:func:`repro.scenario.compare_protocols`), which builds one
    single-round :class:`repro.scenario.ScenarioSpec` per protocol and runs
    it on the netsim executor. Outputs are unchanged.

    Default (``protocols=None``) reproduces the paper's two-column tables:
    ``full_dissemination=False`` measures one exchange step per round;
    ``True`` runs until every node holds all N models (Table I semantics).
    Passing ``protocols`` (names from :func:`repro.core.plan.make_policy`)
    instead runs each named policy to completion over the same overlay.
    """
    from ..scenario.runner import compare_protocols as _compare  # lazy: no cycle

    return _compare(topology, model_mb, n=n, seed=seed, spec=spec,
                    full_dissemination=full_dissemination,
                    protocols=protocols, n_segments=n_segments)
