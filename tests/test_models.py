"""Per-arch smoke tests (reduced variants) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import Batch, build_model

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=64):
    kw = {}
    if cfg.family == "audio":
        kw["encoder_frames"] = jax.random.normal(KEY, (b, cfg.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        kw["patch_embeddings"] = jax.random.normal(KEY, (b, cfg.n_patches, cfg.d_model))
    return Batch(
        tokens=jax.random.randint(KEY, (b, s), 0, cfg.vocab),
        labels=jax.random.randint(KEY, (b, s), 0, cfg.vocab),
        **kw,
    )


@pytest.mark.parametrize("arch", list_archs())
class TestSmoke:
    """Assigned requirement: reduced variant, one forward/train step on CPU,
    output shapes + no NaNs."""

    def test_train_step(self, arch):
        cfg = get_arch(arch).smoke_variant()
        model = build_model(cfg)
        params = model.init(KEY)
        batch = make_batch(cfg)
        logits, aux = jax.jit(model.forward)(params, batch)
        assert logits.shape == (2, 64, max(512, cfg.vocab))  # padded vocab
        assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab])))
        loss = jax.jit(model.train_loss)(params, batch)
        assert bool(jnp.isfinite(loss))
        grads = jax.jit(jax.grad(model.train_loss))(params, batch)
        gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                 for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_decode_step(self, arch):
        cfg = get_arch(arch).smoke_variant()
        model = build_model(cfg)
        params = model.init(KEY)
        cache = model.init_cache(2, 128)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, cache2 = jax.jit(model.decode_step)(params, tok,
                                                    jnp.zeros(2, jnp.int32), cache)
        assert logits.shape[0:2] == (2, 1)
        assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab])))
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)


DECODE_CONSISTENCY_ARCHS = [
    "smollm-360m", "gemma2-2b", "falcon-mamba-7b", "zamba2-7b",
    "qwen3-moe-30b-a3b", "whisper-tiny",
]


@pytest.mark.parametrize("arch", DECODE_CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode token-by-token must reproduce the full forward
    logits (validates cache update, ring buffers, rope positions, SSM state)."""
    cfg = get_arch(arch).smoke_variant()
    if cfg.n_experts:
        # capacity-based MoE drops tokens under contention; full-seq and
        # single-token dispatch drop differently, so disable drops here
        cfg = cfg.replace(moe_capacity_factor=100.0)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 24
    batch = make_batch(cfg, b, s)
    full_logits, _ = jax.jit(model.forward)(params, batch)

    cache = model.init_cache(b, 64)
    if cfg.family == "audio":
        # fill cross-attention cache from the encoder output like prefill would
        cache = _fill_whisper_cross(model, params, batch, cache)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(s):
        tok = batch.tokens[:, t : t + 1]
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = step(params, tok, pos, cache)
        errs.append(float(jnp.abs(
            logits[:, 0, : cfg.vocab] - full_logits[:, t, : cfg.vocab]).max()))
    assert max(errs) < 5e-2, f"max abs logit err {max(errs)}"


def _fill_whisper_cross(model, params, batch, cache):
    import jax.numpy as jnp

    from repro.models.layers import rms_norm

    cfg = model.cfg
    frames = batch.encoder_frames.astype(model.dtype)
    bsz, f, _ = frames.shape
    fpos = jnp.broadcast_to(jnp.arange(f), (bsz, f))
    from repro.models import attention as attn_lib
    from repro.models.layers import mlp

    def enc_body(carry, block):
        x, fpos = carry
        h = attn_lib.attention(block["attn"], rms_norm(x, block["ln1"]), fpos,
                               causal=False, rope_theta=cfg.rope_theta)
        x = x + h
        x = x + mlp(block["mlp"], rms_norm(x, block["ln2"]))
        return (x, fpos), None

    (enc, _), _ = jax.lax.scan(enc_body, (frames, fpos), params["enc_blocks"])
    enc = rms_norm(enc, params["enc_final_norm"])

    def per_layer(block):
        kc = jnp.einsum("bsd,dhk->bshk", enc, block["cross"]["wk"])
        vc = jnp.einsum("bsd,dhk->bshk", enc, block["cross"]["wv"])
        return kc, vc

    kcs, vcs = jax.vmap(per_layer)(params["blocks"])
    return dict(cache, cross_k=kcs.astype(cache["cross_k"].dtype),
                cross_v=vcs.astype(cache["cross_v"].dtype))


def test_vlm_prefix_is_bidirectional():
    """PaliGemma: patch tokens see each other regardless of order."""
    cfg = get_arch("paligemma-3b").smoke_variant()
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 1, 8
    patches = jax.random.normal(KEY, (b, cfg.n_patches, cfg.d_model))
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    base = model.forward(params, Batch(tokens=tokens, patch_embeddings=patches))[0]
    # permuting *later* patch rows must change text logits if prefix is
    # bidirectional (causal-only would hide later patches from earlier ones,
    # but text comes after all patches, so instead check: zeroing the LAST
    # patch changes the FIRST text logit — visible only via bidirectionality
    # + text attending to the whole prefix)
    patches2 = patches.at[:, -1].set(0.0)
    out2 = model.forward(params, Batch(tokens=tokens, patch_embeddings=patches2))[0]
    assert float(jnp.abs(base[:, 0] - out2[:, 0]).max()) > 1e-6


def test_gemma2_softcap_active():
    cfg = get_arch("gemma2-2b").smoke_variant()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, 1, 16)
    logits, _ = model.forward(params, batch)
    assert float(jnp.abs(logits[..., : cfg.vocab]).max()) <= cfg.final_logit_softcap + 1e-3


def test_long_context_variant_windows():
    cfg = get_arch("granite-3-2b").smoke_variant()
    m_short = build_model(cfg, "train_4k")
    m_long = build_model(cfg, "long_500k")
    assert m_long.long_context and not m_short.long_context
    cache = m_long.init_cache(1, 4096)
    # ring buffer: windowed cache length == sliding_window
    assert cache["kv"]["k"].shape[2] == cfg.sliding_window
