"""paligemma-3b — SigLIP + gemma VLM; vision encoder/projector is a STUB
(precomputed patch embeddings) per the assignment. [arXiv:2407.07726]"""
from .base import ArchConfig, register

PALIGEMMA_3B = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,   # MQA
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    n_patches=256,
    sliding_window=4096,  # long_500k variant only
    node_axes=("pod", "data"),
))
