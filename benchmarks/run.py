"""Benchmark driver — one section per paper table / system report.

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys


def main() -> None:
    csv_rows = []
    from . import (coloring_compare, gossip_traffic, kernel_bench,
                   paper_tables, roofline_report, train_bench)

    print("name,us_per_call,derived")
    paper_tables.run(csv_rows)
    coloring_compare.run(csv_rows)
    gossip_traffic.run(csv_rows)
    kernel_bench.run(csv_rows)
    train_bench.run(csv_rows)
    roofline_report.run(csv_rows)
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
