"""Core MOSGU library: plan IR, graphs, schedules, gossip, moderator, netsim."""
from .graph import (  # noqa: F401
    Graph,
    TopologySpec,
    build_mst,
    color_graph,
    is_proper_coloring,
    make_topology,
    mst_boruvka,
    mst_kruskal,
    mst_prim,
    slot_length_for_colors,
    slot_length_s,
    subnet_of,
)
from .gossip import GossipEngine, GossipNode, QueueEntry, fedavg_numpy  # noqa: F401
from .moderator import ConnectivityReport, Moderator, SchedulePacket  # noqa: F401
from .network import (  # noqa: F401
    NETWORK_PRESETS,
    CompiledNetwork,
    NetworkSpec,
    TimingEstimate,
    TimingProfile,
    as_network_model,
    estimate_timing,
    get_preset,
    register_preset,
    router_graph_edges,
    slot_length_for_network,
)
from .plan import (  # noqa: F401
    BroadcastOncePolicy,
    CommPolicy,
    Deliveries,
    DisseminationPolicy,
    FloodingPolicy,
    MstExchangePolicy,
    ReplayPolicy,
    SegmentedGossipPolicy,
    SlotSends,
    TreeAllreducePolicy,
    compile_policy,
    make_policy,
    measure_policy,
)
from .protocol import MOSGUConfig, MOSGUProtocol  # noqa: F401
from .schedule import (  # noqa: F401
    PermStep,
    Slot,
    SlotPlan,
    compile_dissemination,
    compile_flooding,
    compile_segmented,
    compile_tree_allreduce,
    decompose_matchings,
    link_contention_profile,
    plan_to_perm_steps,
)
