"""Runtime gossip engine — the slot-synchronous IR interpreter with payloads.

This is the *dynamic* executor of the communication-plan IR in
:mod:`repro.core.plan`: the policy owns the protocol state machine (FIFO
queues, phase tracking), while the engine moves real payload objects and
supports the behaviours the static compiler cannot express:

* transient link failures with retransmission in the node's next turn
  (paper III-D: "if the network temporarily disrupts during transmission,
  the model will be kept in F and retransmitted"),
* nodes joining/leaving between rounds (handled upstream by the moderator,
  which recompiles MST/colors),
* arbitrary payloads (numpy arrays, pytrees, byte strings).

Equivalence with the compiled plans (no failures) is enforced by tests —
since both now interpret the *same* policy, slot-for-slot agreement is a
property of the architecture, not a coincidence of two implementations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

import numpy as np

from ..compress import Codec, EncodedPayload
from .graph import Graph
from .plan import CommPolicy, DisseminationPolicy, Send


@dataclass
class QueueEntry:
    owner: int  # payload id (model owner; owner*S+seg for segmented gossip)
    round_idx: int
    payload: Any = None
    predecessor: int = -1  # node we received it from; -1 = locally produced


@dataclass
class GossipNode:
    """One DFL participant's view: id, neighbours, and received payloads."""

    node_id: int
    neighbors: List[int]
    received: Dict[int, QueueEntry] = field(default_factory=dict)

    @property
    def degree(self) -> int:
        return len(self.neighbors)


@dataclass
class SlotReport:
    slot_idx: int
    color: int
    sends: List[Send]  # (src, dst, payload_id)
    dropped: List[Send]  # failed transfers (kept in F)


class GossipEngine:
    """Slot-synchronous runtime executor of a communication policy.

    By default runs the paper's MOSGU dissemination over an MST; pass any
    slot policy from :mod:`repro.core.plan` (segmented gossip, tree
    all-reduce, flooding) to execute it with live payloads instead.

    ``drop_fn(slot_idx, src, dst)`` may return True to simulate a transient
    link failure; the policy then keeps the entry at the *head* of the
    sender's FIFO and it is retransmitted on the node's next active slot.

    ``codec`` (a :class:`repro.compress.Codec`) puts the wire format in the
    loop: each node's round payloads are *encoded* at ``begin_round`` (with
    per-payload error-feedback residuals that persist across rounds — what
    top-k drops this round is compensated next round), the queues move
    :class:`EncodedPayload` objects whose exact ``bytes_on_wire`` are tallied
    per round (``round_wire_bytes``), and :meth:`aggregate` decodes before
    combining (FedAvg sees what actually crossed the network).
    """

    def __init__(
        self,
        mst: Optional[Graph] = None,
        colors: Optional[np.ndarray] = None,
        first_color: int = 0,
        drop_fn: Optional[Callable[[int, int, int], bool]] = None,
        policy: Optional[CommPolicy] = None,
        codec: Optional[Codec] = None,
    ) -> None:
        if policy is None:
            if mst is None or colors is None:
                raise ValueError("need either a policy or (mst, colors)")
            policy = DisseminationPolicy(mst, colors, first_color)
        self.policy = policy
        self.mst = policy.graph if policy.graph is not None else mst
        self.colors = policy.colors
        self.drop_fn = drop_fn
        graph = self.mst
        self.nodes = [
            GossipNode(u, graph.neighbors(u) if graph is not None else [])
            for u in range(policy.n)
        ]
        self.slot_idx = 0
        self.reports: List[SlotReport] = []
        self._store: Dict[int, Any] = {}
        self._round_idx = 0
        self.codec = codec
        # per-payload-id error-feedback residuals; persist across rounds
        self._ef_states: Dict[int, Any] = {}
        self.round_wire_bytes = 0

    @property
    def n(self) -> int:
        return self.policy.n

    # -- round lifecycle ----------------------------------------------------
    def begin_round(self, round_idx: int, payloads: Optional[Sequence[Any]] = None) -> None:
        self.policy.reset()
        self._round_idx = round_idx
        self._store = {}
        self.round_wire_bytes = 0
        for node in self.nodes:
            node.received.clear()
        for u, node in enumerate(self.nodes):
            pids = self.policy.initial_payload_ids(u)
            if payloads is not None and pids:
                if len(pids) == 1:
                    self._store[pids[0]] = self._encode(pids[0], payloads[u])
                else:
                    parts = payloads[u]
                    if not isinstance(parts, (list, tuple)) or len(parts) != len(pids):
                        raise ValueError(
                            f"node {u}: segmented policies need one payload per "
                            f"segment ({len(pids)} expected)")
                    for pid, part in zip(pids, parts):
                        self._store[pid] = self._encode(pid, part)
            for pid in pids:
                node.received[pid] = QueueEntry(pid, round_idx, self._store.get(pid), -1)

    def _encode(self, pid: int, payload: Any) -> Any:
        """Encode a node's own payload for the wire, carrying the payload's
        error-feedback residual from the previous round."""
        if self.codec is None or payload is None:
            return payload
        state = self._ef_states.get(pid, self.codec.init_state())
        encoded, self._ef_states[pid] = self.codec.encode(payload, state)
        return encoded

    def _decode(self, payload: Any) -> Any:
        if self.codec is not None and isinstance(payload, EncodedPayload):
            return self.codec.decode(payload)
        return payload

    def step(self) -> SlotReport:
        """Advance one colored slot."""
        sends = self.policy.emit(self.slot_idx)
        tuples = sends.tuples()
        ok = np.ones(len(tuples), dtype=bool)
        report = SlotReport(self.slot_idx, sends.color, [], [])
        for i, (src, dst, pid) in enumerate(tuples):
            if self.drop_fn is not None and self.drop_fn(self.slot_idx, src, dst):
                ok[i] = False
                report.dropped.append((src, dst, pid))
            else:
                report.sends.append((src, dst, pid))
            stored = self._store.get(pid)
            if isinstance(stored, EncodedPayload):  # dropped sends burn wire too
                self.round_wire_bytes += stored.bytes_on_wire
        delivered = self.policy.commit(self.slot_idx, sends, ok)
        for src, dst, pid in zip(delivered.src.tolist(), delivered.dst.tolist(),
                                 delivered.payload.tolist()):
            self.nodes[dst].received[pid] = QueueEntry(
                pid, self._round_idx, self._store.get(pid), src)
        self.slot_idx += 1
        self.reports.append(report)
        return report

    def run_round(
        self, round_idx: int, payloads: Optional[Sequence[Any]] = None, max_slots: int = 100_000
    ) -> int:
        """Run slots until the policy completes; return number of slots used."""
        from .. import obs

        self.begin_round(round_idx, payloads)
        start = self.slot_idx
        rec = obs.get()
        while not self.is_round_complete():
            if self.slot_idx - start >= max_slots:
                raise RuntimeError("gossip round did not converge")
            if rec.enabled:
                wire0 = self.round_wire_bytes
                with rec.span(f"slot {self.slot_idx}", cat="engine-slot",
                              track="engine", round=round_idx):
                    report = self.step()
                rec.count("engine.slot_sends", len(report.sends))
                if report.dropped:
                    rec.count("engine.slot_drops", len(report.dropped))
                rec.count("engine.slot_wire_bytes",
                          self.round_wire_bytes - wire0)
            else:
                self.step()
        return self.slot_idx - start

    def is_round_complete(self) -> bool:
        return self.policy.done()

    # -- inspection ---------------------------------------------------------
    def queue_snapshot(self) -> List[List[int]]:
        return self.policy.queue_snapshot()

    def received_snapshot(self) -> List[Set[int]]:
        return [set(nd.received.keys()) for nd in self.nodes]

    def aggregate(self, combine: Callable[[List[Any]], Any]) -> List[Any]:
        """Per-node aggregation over all received payloads (e.g. FedAvg).

        For segmented policies each node returns a list of S per-segment
        aggregates (segment j combines every owner's j-th segment), which
        concatenate back into the aggregated model. Codec-encoded payloads
        are decoded first: FedAvg averages what crossed the network, not the
        senders' local tensors.
        """
        S = getattr(self.policy, "segments", 1)
        out: List[Any] = []
        for nd in self.nodes:
            if S == 1:
                out.append(combine([self._decode(nd.received[o].payload)
                                    for o in sorted(nd.received)]))
            else:
                out.append([
                    combine([self._decode(nd.received[pid].payload)
                             for pid in sorted(nd.received) if pid % S == j])
                    for j in range(S)
                ])
        return out


def fedavg_numpy(payloads: List[Any]) -> Any:
    """Uniform FedAvg over numpy pytrees (nested dict/list of arrays)."""
    def avg(*xs):
        return sum(xs) / len(xs)

    def tree_map(fn, *trees):
        t0 = trees[0]
        if isinstance(t0, dict):
            return {k: tree_map(fn, *[t[k] for t in trees]) for k in t0}
        if isinstance(t0, (list, tuple)):
            return type(t0)(tree_map(fn, *parts) for parts in zip(*trees))
        return fn(*trees)

    return tree_map(avg, *payloads)
