"""Traffic/slot accounting for the compiled gossip plans — the paper's
structural claims (redundancy removal, bounded concurrency) at TPU scale,
plus analytic bytes-on-wire for every gossip mode at each arch's size."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_arch, list_archs
from repro.core.graph import Graph, TopologySpec, build_mst, color_graph, make_topology
from repro.core.schedule import compile_dissemination, compile_flooding, compile_tree_allreduce


class _FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)


def run(csv_rows):
    t0 = time.time()
    # structural claims across topologies and N
    for kind in ("complete", "erdos_renyi", "watts_strogatz", "barabasi_albert"):
        for n in (10, 16, 32):
            g = make_topology(TopologySpec(kind=kind, n=n, seed=1))
            mst = build_mst(g)
            colors = color_graph(mst)
            diss = compile_dissemination(mst, colors)
            tree = compile_tree_allreduce(mst, colors)
            flood = compile_flooding(g)
            us = (time.time() - t0) * 1e6
            csv_rows.append((
                f"gossip_plan/{kind}/n{n}", us,
                f"diss_tx{diss.total_transmissions()}_flood_tx"
                f"{flood.total_transmissions()}_tree_tx{tree.total_transmissions()}"
                f"_slots{diss.n_slots}",
            ))

    # per-arch bytes on the wire for one communication round (32-node mesh)
    from repro.dfl.collectives import GossipPlan, gossip_collective_bytes

    mesh = _FakeMesh(pod=2, data=16, model=16)
    for arch in list_archs():
        cfg = get_arch(arch)
        plan = GossipPlan.build(mesh, cfg.node_axes)
        pbytes = cfg.param_count() * 2  # bf16
        us = (time.time() - t0) * 1e6
        for mode in ("dissemination", "tree_allreduce", "flooding", "allreduce_ref"):
            gb = gossip_collective_bytes(mode, plan, pbytes) / 2**30
            csv_rows.append((f"gossip_bytes/{arch}/{mode}", us, f"{gb:.1f}GiB"))
