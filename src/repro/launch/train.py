"""End-to-end DFL training driver.

Runs real steps on whatever devices exist (CPU smoke: reduced arch variant;
TPU: full config), with MOSGU gossip every step, checkpointing, and
moderator rotation each communication round.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --mesh 1x2x2 --gossip tree_allreduce

With ``--scenario NAME`` the run is driven by a declarative registry
scenario (:mod:`repro.scenario`): the scenario's protocol picks the gossip
mode, its round count the number of communication rounds, and its churn
schedule fires inside :class:`repro.dfl.session.DFLSession` (replan +
recompile on every membership change, moderator rotation every round):

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --mesh 1x4x2 --scenario churn_storm

With ``--sweep NAME`` the run is one cell of a registered experiment grid
(:mod:`repro.scenario.sweep`) — the launcher-array pattern: ``--cell K``
trains the K-th expanded cell's scenario (one cell per process / SLURM
array index), while ``--sweep NAME`` alone prints the expanded grid with
its plan-executor accounting (a dry-run of the whole table) and exits:

  PYTHONPATH=src python -m repro.launch.train --sweep codec_x_protocol
  PYTHONPATH=src python -m repro.launch.train --smoke --mesh 1x4x2 \
      --sweep codec_x_protocol --cell 3
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", help="use the reduced variant")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-per-node", type=int, default=2)
    ap.add_argument("--mesh", default="", help="e.g. 2x2 (data x model) or 1x2x2")
    ap.add_argument("--gossip", default="tree_allreduce")
    ap.add_argument("--scenario", default="",
                    help="registry scenario driving protocol/rounds/churn "
                         "(see repro.scenario.scenarios.names())")
    ap.add_argument("--sweep", default="",
                    help="registered sweep grid; with --cell K trains that "
                         "cell's scenario, alone prints the expanded grid "
                         "(see repro.scenario.scenarios.sweep_names())")
    ap.add_argument("--cell", type=int, default=-1,
                    help="cell index into --sweep (the launcher-array slot)")
    ap.add_argument("--gossip-interval", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--trace", default="",
                    help="record an observability trace of the run and write "
                         "Chrome/Perfetto JSON to this path")
    args = ap.parse_args()

    if args.trace:
        from .. import obs

        obs.set_recorder(obs.Recorder())

    if args.sweep and args.scenario:
        raise SystemExit("--sweep and --scenario are mutually exclusive: "
                         "a sweep cell *is* the scenario for the run")
    if args.cell >= 0 and not args.sweep:
        raise SystemExit("--cell is an index into --sweep; pass a sweep name "
                         "(see repro.scenario.scenarios.sweep_names())")
    sweep_cell = None
    if args.sweep:
        # resolved before jax comes up: the dry-run path never needs devices
        from ..scenario import run_sweep, scenarios

        sweep = scenarios.get_sweep(args.sweep)
        cells = sweep.cells()
        if args.cell < 0:
            result = run_sweep(sweep, executor="plan")
            print(f"sweep {sweep.name!r}: {len(cells)} cells "
                  f"(pass --cell K to train one)")
            for row in result.table():
                coords = ",".join(f"{k}={v}" for k, v in row.items()
                                  if k in sweep.axes())
                print(f"  [{row['cell']:3d}] {coords:40s} "
                      f"tx={row['transmissions']:6d} "
                      f"wire={row['bytes_on_wire_mb']:10.1f}MB")
            return
        if not (0 <= args.cell < len(cells)):
            raise SystemExit(
                f"--cell {args.cell} outside [0, {len(cells)}) for sweep "
                f"{sweep.name!r}")
        sweep_cell = cells[args.cell]
        print(f"sweep {sweep.name!r} cell {args.cell}: "
              f"{sweep_cell.spec.name}")

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        import os

        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={int(np.prod(dims))}"
        )
    import jax
    import jax.numpy as jnp

    from ..checkpoint import save_pytree
    from ..configs import get_arch
    from ..data import DataConfig, FederatedData
    from ..dfl import DFLConfig, DFLTrainer
    from ..models import Batch, build_model

    scenario = None
    codec = ""
    if sweep_cell is not None:
        from ..scenario import resolve_gossip_mode

        scenario = sweep_cell.spec
        args.gossip = resolve_gossip_mode(scenario.protocol)
        args.steps = scenario.rounds
        print(f"cell scenario: protocol={scenario.protocol} "
              f"codec={scenario.codec} rounds={scenario.rounds}")
    elif args.scenario:
        from ..scenario import resolve_gossip_mode, scenarios

        scenario = scenarios.get(args.scenario)
        args.gossip = resolve_gossip_mode(scenario.protocol)
        args.steps = scenario.rounds
        print(f"scenario {scenario.name!r}: protocol={scenario.protocol} "
              f"rounds={scenario.rounds} churn={len(scenario.churn)} events")
    if scenario is not None:
        # the scenario's wire codec drives the trainer ("" = raw fp32, the
        # DFLConfig default — same resolution as examples/train_dfl.py)
        codec = scenario.codec if scenario.codec != "fp32" else ""

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke_variant()
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[: int(np.prod(dims))]).reshape(dims), names
        )
    else:
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    model = build_model(cfg)
    dfl = DFLConfig(gossip_mode=args.gossip, gossip_interval=args.gossip_interval,
                    lr=args.lr, total_steps=args.steps, codec=codec)
    trainer = DFLTrainer(model, mesh, dfl)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M nodes={trainer.plan.n_nodes} "
          f"mst_slots={trainer.plan.dissemination.n_slots} gossip={args.gossip}")

    state = trainer.init_state(jax.random.PRNGKey(0))
    n_nodes = max(trainer.plan.n_nodes, 1)
    data = FederatedData(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len,
        batch_per_node=args.batch_per_node, n_nodes=n_nodes,
    ))

    def make_batch():
        tok, lab = data.global_batch()
        kw = {}
        b = tok.shape[0]
        if cfg.family == "audio":
            kw["encoder_frames"] = jnp.zeros((b, cfg.n_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            kw["patch_embeddings"] = jnp.zeros((b, cfg.n_patches, cfg.d_model), jnp.float32)
        return Batch(tokens=jnp.asarray(tok), labels=jnp.asarray(lab), **kw)

    batch = make_batch()
    if scenario is not None:
        from ..dfl.session import DFLSession, run_scenario_rounds

        session = DFLSession(trainer, scenario=scenario)
        t0 = time.time()
        state, _ = run_scenario_rounds(session, state, batch, make_batch)
        print(f"done: {scenario.rounds} scenario rounds in {time.time()-t0:.1f}s")
        _flush_trace(args.trace)
        return

    step_fn = trainer.jitted_train_step(jax.eval_shape(lambda: state),
                                        jax.eval_shape(lambda: batch))
    from .. import obs

    rec = obs.get()
    t0 = time.time()
    for i in range(args.steps):
        if rec.enabled:
            with rec.span("train:step", cat="train", track="train", step=i,
                          gossip=(i + 1) % max(args.gossip_interval, 1) == 0):
                state, metrics = step_fn(state, batch)
        else:
            state, metrics = step_fn(state, batch)
        batch = make_batch()
        if (i + 1) % args.log_every == 0 or i == 0:
            print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if args.checkpoint_dir and args.checkpoint_every and (i + 1) % args.checkpoint_every == 0:
            save_pytree(f"{args.checkpoint_dir}/step{i+1:08d}",
                        jax.device_get(state.params),
                        {"step": i + 1, "arch": cfg.name})
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")
    _flush_trace(args.trace)


def _flush_trace(path: str) -> None:
    """Uninstall the run's recorder and export it as a Perfetto trace."""
    if not path:
        return
    from .. import obs
    from ..obs import write_trace

    rec = obs.set_recorder(obs.NULL_RECORDER)
    write_trace(rec, path)
    print(f"wrote {path} ({len(rec.spans)} spans) — open in ui.perfetto.dev")


if __name__ == "__main__":
    main()
