"""Reproduce the paper's Tables III/IV/V on the testbed simulator.

One benchmark per table: bandwidth (MB/s), single-transfer time (s), and
total communication-round time (s), for broadcast vs MOSGU across the four
topologies and the seven CNN payloads.
"""
from __future__ import annotations

import time
from typing import Dict

from repro.configs.paper_payloads import PAPER_PAYLOADS
from repro.core.netsim import TestbedSpec, compare_protocols

TOPOLOGIES = ("erdos_renyi", "watts_strogatz", "barabasi_albert", "complete")
CODES = ("v3s", "v2", "b0", "v3l", "b1", "b2", "b3")

# Paper values for side-by-side comparison (broadcast is one merged column).
PAPER_BROADCAST = {  # code -> (bandwidth MB/s, transfer s, total s)
    "v3s": (1.785, 6.500, 10.0), "v2": (1.096, 12.773, 24.0),
    "b0": (1.011, 20.970, 30.0), "v3l": (1.066, 20.255, 30.0),
    "b1": (0.842, 37.060, 55.0), "b2": (0.839, 42.864, 61.0),
    "b3": (0.767, 62.576, 83.0),
}
PAPER_MOSGU_BW = {  # (topology, code) -> MB/s (Table III)
    ("erdos_renyi", "v3s"): 5.353, ("erdos_renyi", "b3"): 6.022,
    ("watts_strogatz", "v3s"): 4.640, ("watts_strogatz", "b3"): 6.146,
    ("barabasi_albert", "v3s"): 3.969, ("barabasi_albert", "b3"): 5.522,
    ("complete", "v3s"): 4.349, ("complete", "b3"): 4.610,
}


def simulate_all(seed: int = 3) -> Dict:
    spec = TestbedSpec()
    out = {}
    for topo in TOPOLOGIES:
        for code in CODES:
            mb = PAPER_PAYLOADS[code].capacity_mb
            out[(topo, code)] = compare_protocols(topo, mb, seed=seed, spec=spec)
    return out


def run(csv_rows) -> Dict:
    t0 = time.time()
    results = simulate_all()
    us = (time.time() - t0) * 1e6 / len(results)

    gains, speeds = [], []
    for (topo, code), r in sorted(results.items()):
        b, m = r["broadcast"], r["mosgu"]
        gain = m.mean_bandwidth_mbps / b.mean_bandwidth_mbps
        speed = b.total_time_s / m.total_time_s
        gains.append(gain)
        speeds.append(speed)
        csv_rows.append((f"table3_bandwidth/{topo}/{code}", us,
                         f"{m.mean_bandwidth_mbps:.3f}MBps_gain{gain:.2f}x"))
        csv_rows.append((f"table4_transfer/{topo}/{code}", us,
                         f"{m.mean_transfer_s:.3f}s_vs_bcast{b.mean_transfer_s:.1f}s"))
        csv_rows.append((f"table5_round/{topo}/{code}", us,
                         f"{m.total_time_s:.2f}s_speedup{speed:.2f}x"))
    csv_rows.append(("table3_bandwidth/max_gain", us, f"{max(gains):.2f}x_paper8.01x"))
    csv_rows.append(("table5_round/max_speedup", us, f"{max(speeds):.2f}x_paper4.38x"))
    return results


def markdown_tables(results) -> str:
    lines = []
    for title, metric in [
        ("Table III — bandwidth (MB/s)", "mean_bandwidth_mbps"),
        ("Table IV — single transfer time (s)", "mean_transfer_s"),
        ("Table V — total round time (s)", "total_time_s"),
    ]:
        lines.append(f"\n### {title}\n")
        lines.append("| topology | " + " | ".join(CODES) + " | broadcast (ours / paper, b3) |")
        lines.append("|" + "---|" * (len(CODES) + 2))
        for topo in TOPOLOGIES:
            vals = [f"{getattr(results[(topo, c)]['mosgu'], metric):.2f}" for c in CODES]
            b = getattr(results[(topo, "b3")]["broadcast"], metric)
            paper_b = {"mean_bandwidth_mbps": 0.767, "mean_transfer_s": 62.576,
                       "total_time_s": 83.0}[metric]
            lines.append(f"| {topo} | " + " | ".join(vals) +
                         f" | {b:.2f} / {paper_b} |")
    return "\n".join(lines)
