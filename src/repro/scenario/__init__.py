"""Declarative scenario API: declare an experiment once, run it anywhere.

    from repro.scenario import ScenarioSpec, run_scenario, scenarios

    spec = scenarios.get("paper_table3")        # or build a ScenarioSpec
    result = run_scenario(spec, executor="netsim")
    print(result.to_json())

Whole experiment grids are one call through the sweep API:

    from repro.scenario import SweepSpec, run_sweep, scenarios

    table = run_sweep(scenarios.get_sweep("table3_full"), executor="netsim")
    print(table.to_json())          # flat cell table + per-axis marginals

See :mod:`repro.scenario.spec` for what a scenario declares,
:mod:`repro.scenario.executors` for the pluggable executor registry,
:mod:`repro.scenario.sweep` for grid/zip sweep semantics,
:mod:`repro.scenario.cache` for the cross-cell plan cache, and
:mod:`repro.scenario.registry` for the named workloads.
"""
from . import executors  # noqa: F401
from . import registry as scenarios  # noqa: F401
from .cache import PlanCache  # noqa: F401
from .executors import Executor, RoundContext  # noqa: F401
from .registry import register, register_sweep  # noqa: F401
from .runner import (  # noqa: F401
    EXECUTORS,
    GOSSIP_MODES,
    compare_protocols,
    resolve_gossip_mode,
    run_scenario,
)
from .spec import (  # noqa: F401
    ChurnEvent,
    RoundReport,
    ScenarioResult,
    ScenarioSpec,
    resolve_payload_mb,
)
from .sweep import (  # noqa: F401
    SweepCell,
    SweepCellResult,
    SweepResult,
    SweepSpec,
    run_sweep,
)
