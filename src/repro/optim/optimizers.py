"""Optimizers from scratch (no optax): SGD, momentum, AdamW.

Moment dtype and the fp32 master copy are configurable per architecture so
multi-billion-parameter replicas fit per-chip HBM budgets (ArchConfig
`optimizer_dtype` / `use_master_fp32`).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
PyTree = Any


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, lr * cos)

    return fn


def linear_schedule(lr: float, warmup: int, total: int):
    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        decay = lr * jnp.clip(1 - (step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, decay)

    return fn


# ---------------------------------------------------------------------------
# gradient utilities
# ---------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Optimizer:
    """(init, update) pair. update returns (new_params, new_state)."""

    init: Callable[[Params], PyTree]
    update: Callable[[Params, PyTree, PyTree, jax.Array], Tuple[Params, PyTree]]
    name: str = "optimizer"


def sgd(schedule: Callable, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {}

    def update(params, grads, state, step):
        lr = schedule(step)

        def upd(p, g):
            g32 = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p32
            return (p32 - lr * g32).astype(p.dtype)

        return jax.tree.map(upd, params, grads), state

    return Optimizer(init, update, "sgd")


def momentum_sgd(schedule: Callable, beta: float = 0.9, weight_decay: float = 0.0,
                 moment_dtype: Any = jnp.float32) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)}

    def update(params, grads, state, step):
        lr = schedule(step)

        def upd(p, g, m):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m32 = beta * m.astype(jnp.float32) + g32
            return (p.astype(jnp.float32) - lr * m32).astype(p.dtype), m32.astype(moment_dtype)

        out = jax.tree.map(upd, params, grads, state["m"])
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m}

    return Optimizer(init, update, "momentum_sgd")


def adamw(
    schedule: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype: Any = jnp.float32,
    master_fp32: bool = True,
) -> Optimizer:
    """AdamW with configurable moment dtype and optional fp32 master weights."""

    def init(params):
        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        }
        if master_fp32:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return state

    def update(params, grads, state, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v, master):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / bc1
            vhat = v32 / bc2
            base = master.astype(jnp.float32) if master is not None else p.astype(jnp.float32)
            step_vec = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * base
            new_master = base - lr * step_vec
            return new_master.astype(p.dtype), m32.astype(moment_dtype), v32.astype(moment_dtype), (
                new_master if master is not None else None
            )

        masters = state.get("master", jax.tree.map(lambda p: None, params))
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_ma = treedef.flatten_up_to(masters)
        outs = [upd(p, g, m, v, ma) for p, g, m, v, ma in
                zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_state = {
            "m": treedef.unflatten([o[1] for o in outs]),
            "v": treedef.unflatten([o[2] for o in outs]),
        }
        if "master" in state:
            new_state["master"] = treedef.unflatten([o[3] for o in outs])
        return new_p, new_state

    return Optimizer(init, update, "adamw")


def adafactor(
    schedule: Callable,
    b2: float = 0.999,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adafactor (Shazeer & Stern) without momentum: the second moment of any
    rank>=2 tensor is stored as a rank-1 row/col factorization, shrinking
    optimizer state from 2x params to ~params/dim — the realistic choice for
    training 100B+ replicas under DFL (each node holds full state)."""

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(leaf, params)}

    def update(params, grads, state, step):
        lr = schedule(step)

        def upd(p, g, st):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = b2 * st["vr"] + (1 - b2) * g2.mean(axis=-1)
                vc = b2 * st["vc"] + (1 - b2) * g2.mean(axis=-2)
                # rank-1 reconstruction of the second moment
                denom = vr[..., :, None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], eps)
                u = g32 / jnp.sqrt(jnp.maximum(denom, eps))
                new_st = {"vr": vr, "vc": vc}
            else:
                v = b2 * st["v"] + (1 - b2) * g2
                u = g32 / jnp.sqrt(jnp.maximum(v, eps))
                new_st = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                u = u + weight_decay * p32
            return (p32 - lr * u).astype(p.dtype), new_st

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        return treedef.unflatten([o[0] for o in outs]), {
            "f": treedef.unflatten([o[1] for o in outs])
        }

    return Optimizer(init, update, "adafactor")


def make_optimizer(cfg, lr: float = 3e-4, warmup: int = 100, total: int = 10_000) -> Optimizer:
    """Arch-aware optimizer (kind / moment dtype / master copy from ArchConfig)."""
    kind = getattr(cfg, "optimizer", "adamw")
    sched = cosine_schedule(lr, warmup, total)
    if kind == "adafactor":
        return adafactor(sched)
    if kind == "momentum":
        moment_dtype = jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16" else jnp.float32
        return momentum_sgd(sched, moment_dtype=moment_dtype)
    moment_dtype = jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16" else jnp.float32
    return adamw(sched, moment_dtype=moment_dtype, master_fp32=cfg.use_master_fp32)
