from .checkpoint import (  # noqa: F401
    load_metadata, node_checkpoint_path, restore_pytree, save_pytree,
)
