"""Determinism lint: AST rules enforcing the seeded-RNG / virtual-clock /
stable-fingerprint discipline the plan cache, resume, and bench
reproducibility all assume.

Rules (the ``rule`` field of each :class:`Finding`):

=========================  =============================================
``unseeded-rng``           legacy global ``np.random.*`` draws, unseeded
                           ``np.random.default_rng()`` /
                           ``random.Random()``, and stdlib ``random.*``
                           draws — all derive state from an ambient
                           process-global seed
``wall-clock``             ``time.time()`` / ``perf_counter()`` /
                           ``datetime.now()`` reads inside virtual-clock
                           modules (``core/events.py``, ``core/netsim.py``)
                           and the obs layer — wall time leaking into
                           simulated results
``dict-order-in-``         iteration over ``set()`` / ``frozenset()`` /
``fingerprint``            dict views inside fingerprint/cache-key
                           functions without a ``sorted()`` wrapper —
                           ordering that depends on construction history
``fingerprint-coverage``   a ``ScenarioSpec`` field missing from
                           :data:`SPEC_FIELD_ROLES`, or a plan-identity
                           field not folded into the plan cache's
                           fingerprint/key functions
=========================  =============================================

Findings are suppressed by ``tools/lint_allowlist.txt`` lines of the form
``<path-suffix> <rule> <detail-substring>`` — every intentional exception
(the obs recorder's two wall-clock span timestamps) is visible in one
reviewed file instead of scattered pragmas. ``tools/lint.py`` is the CLI;
CI runs it over ``src/repro/`` and fails on any unsuppressed finding.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

#: legacy numpy global-state draws (np.random.<fn>)
NP_RANDOM_FNS = frozenset({
    "random", "rand", "randn", "randint", "random_integers", "choice",
    "shuffle", "permutation", "normal", "uniform", "standard_normal",
    "binomial", "poisson", "exponential", "beta", "gamma", "sample",
    "random_sample", "bytes",
})

#: stdlib random module draws (random.<fn>) — seed()/getstate() are fine
STDLIB_RANDOM_FNS = frozenset({
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "betavariate",
    "expovariate", "getrandbits", "triangular", "vonmisesvariate",
})

#: wall-clock reads (time.<fn> / datetime.<fn>)
TIME_FNS = frozenset({
    "time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "process_time_ns",
})
DATETIME_FNS = frozenset({"now", "utcnow", "today"})

#: relative-path substrings of modules that run on *virtual* clocks (plus
#: the obs layer, whose few intentional wall reads live in the allowlist)
VIRTUAL_CLOCK_MODULES = ("core/events.py", "core/netsim.py", "obs/")

#: function names treated as fingerprint/cache-key builders by the
#: dict-order rule
FINGERPRINT_FN_RE = re.compile(
    r"(fingerprint|_field_tuple|policy_key|cache_key|_key)$")

#: every ScenarioSpec field, classified by what its value influences.
#: ``plan`` fields are the plan's cache identity and MUST be folded into
#: ``overlay_fingerprint``/``policy_key``; the coverage rule fails when a
#: new field is added without classifying it here (forcing the author to
#: decide whether it changes the plan) or when a ``plan`` field is missing
#: from the key functions.
SPEC_FIELD_ROLES: Dict[str, str] = {
    # plan identity -> must appear in cache.policy_key/overlay_fingerprint
    "overlay": "plan",
    "protocol": "plan",
    "n_segments": "plan",
    "mst_algorithm": "plan",
    "coloring_algorithm": "plan",
    "optimizer": "plan",
    # membership trajectory (cache.trajectory key)
    "rounds": "trajectory",
    "churn": "trajectory",
    # wire accounting (folded into the verified-stage key)
    "payload": "wire",
    "codec": "wire",
    # timing / underlay (cache.timing key via underlay_fingerprint)
    "underlay": "timing",
    "compute_time_s": "timing",
    "compute_jitter_s": "timing",
    "jitter_seed": "timing",
    "max_staleness": "timing",
    # per-run runtime behaviour, deliberately not plan identity
    "drop_rate": "runtime",
    "drop_seed": "runtime",
    "record_events": "runtime",
    "require": "runtime",
    "executors": "runtime",
    # documentation only
    "name": "doc",
    "description": "doc",
}


@dataclass
class Finding:
    """One lint hit, printable as ``path:line: [rule] detail``."""

    path: str
    line: int
    rule: str
    detail: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.detail}"


def _module_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to the canonical module they alias (``np`` ->
    ``numpy``, ``random`` -> ``random``, ``npr`` -> ``numpy.random``)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in ("numpy", "numpy.random", "random", "time",
                              "datetime"):
                    aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module == "numpy":
            for a in node.names:
                if a.name == "random":
                    aliases[a.asname or "random"] = "numpy.random"
        elif isinstance(node, ast.ImportFrom) and node.module == "datetime":
            for a in node.names:
                if a.name == "datetime":
                    aliases[a.asname or "datetime"] = "datetime.datetime"
    return aliases


def _resolve(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted module path of an expression like ``np.random`` / ``time``."""
    if isinstance(node, ast.Name):
        return aliases.get(node.id)
    if isinstance(node, ast.Attribute):
        base = _resolve(node.value, aliases)
        if base is not None:
            return f"{base}.{node.attr}"
    return None


def _check_rng(tree: ast.AST, rel: str, aliases: Dict[str, str],
               out: List[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            continue
        base = _resolve(fn.value, aliases)
        if base == "numpy.random":
            if fn.attr in NP_RANDOM_FNS:
                out.append(Finding(
                    rel, node.lineno, "unseeded-rng",
                    f"legacy global np.random.{fn.attr}() draws from "
                    f"process-global state; use np.random.default_rng(seed)"))
            elif fn.attr == "default_rng" and not node.args:
                out.append(Finding(
                    rel, node.lineno, "unseeded-rng",
                    "np.random.default_rng() without a seed is entropy-"
                    "seeded; pass an explicit seed"))
            elif fn.attr in ("RandomState", "seed") and not node.args:
                out.append(Finding(
                    rel, node.lineno, "unseeded-rng",
                    f"np.random.{fn.attr}() without a seed"))
        elif base == "random":
            if fn.attr in STDLIB_RANDOM_FNS:
                out.append(Finding(
                    rel, node.lineno, "unseeded-rng",
                    f"stdlib random.{fn.attr}() draws from process-global "
                    f"state; use random.Random(seed)"))
            elif fn.attr == "Random" and not node.args:
                out.append(Finding(
                    rel, node.lineno, "unseeded-rng",
                    "random.Random() without a seed is entropy-seeded"))


def _check_wall_clock(tree: ast.AST, rel: str, aliases: Dict[str, str],
                      out: List[Finding]) -> None:
    if not any(tag in rel for tag in VIRTUAL_CLOCK_MODULES):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            continue
        base = _resolve(fn.value, aliases)
        if base == "time" and fn.attr in TIME_FNS:
            out.append(Finding(
                rel, node.lineno, "wall-clock",
                f"time.{fn.attr}() read inside a virtual-clock module"))
        elif base is not None and base.endswith("datetime") and \
                fn.attr in DATETIME_FNS:
            out.append(Finding(
                rel, node.lineno, "wall-clock",
                f"datetime.{fn.attr}() read inside a virtual-clock module"))


def _iter_exprs_of(fn: ast.AST):
    """(line, iter-expression) of every for-loop / comprehension in a
    function body, excluding nested function definitions."""
    for node in ast.walk(fn):
        if isinstance(node, ast.For):
            yield node.lineno, node.iter
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield node.lineno, gen.iter


def _unordered_iter(expr: ast.AST) -> Optional[str]:
    """A description of why iterating ``expr`` has unstable order, or
    ``None``. ``sorted(...)`` at the top level always makes it stable."""
    if not isinstance(expr, ast.Call):
        return None
    fn = expr.func
    if isinstance(fn, ast.Name):
        if fn.id in ("set", "frozenset"):
            return f"iterates {fn.id}(...) (hash order)"
        return None  # sorted(...), tuple(...), list(...), enumerate(...)
    if isinstance(fn, ast.Attribute) and fn.attr in ("keys", "values",
                                                     "items"):
        return (f"iterates .{fn.attr}() (insertion order — depends on "
                f"construction history)")
    return None


def _check_fingerprint_order(tree: ast.AST, rel: str,
                             out: List[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not FINGERPRINT_FN_RE.search(node.name):
            continue
        for line, it in _iter_exprs_of(node):
            why = _unordered_iter(it)
            if why is not None:
                out.append(Finding(
                    rel, line, "dict-order-in-fingerprint",
                    f"fingerprint function {node.name}() {why}; wrap in "
                    f"sorted(...)"))


def _spec_fields(spec_path: str) -> Tuple[int, List[str]]:
    """(class line, annotated field names) of ScenarioSpec, by pure AST —
    the lint never imports the tree it checks."""
    with open(spec_path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=spec_path)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "ScenarioSpec":
            fields = [s.target.id for s in node.body
                      if isinstance(s, ast.AnnAssign)
                      and isinstance(s.target, ast.Name)]
            return node.lineno, fields
    return 0, []


def _key_fn_spec_attrs(cache_path: str) -> Set[str]:
    """Every ``spec.<attr>`` access inside the plan-identity key builders
    (``_base_overlay_fingerprint`` / ``overlay_fingerprint`` /
    ``policy_key``) of scenario/cache.py."""
    with open(cache_path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=cache_path)
    attrs: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if node.name not in ("_base_overlay_fingerprint",
                             "overlay_fingerprint", "policy_key"):
            continue
        for sub in ast.walk(node):
            if (isinstance(sub, ast.Attribute)
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id == "spec"):
                attrs.add(sub.attr)
    return attrs


def check_fingerprint_coverage(root: str) -> List[Finding]:
    """The semantic half of the lint: every ``ScenarioSpec`` field must be
    classified in :data:`SPEC_FIELD_ROLES`, and every ``plan``-role field
    must actually be folded into the plan cache's fingerprint/key
    functions. Catches the classic cache-poisoning bug — a new spec field
    that changes the plan but not its cache key."""
    spec_path = os.path.join(root, "scenario", "spec.py")
    cache_path = os.path.join(root, "scenario", "cache.py")
    if not (os.path.exists(spec_path) and os.path.exists(cache_path)):
        return []  # not linting the repro tree (e.g. a test fixture dir)
    out: List[Finding] = []
    line, fields = _spec_fields(spec_path)
    rel = os.path.join(os.path.basename(root), "scenario", "spec.py")
    for f in fields:
        if f not in SPEC_FIELD_ROLES:
            out.append(Finding(
                rel, line, "fingerprint-coverage",
                f"ScenarioSpec.{f} is not classified in SPEC_FIELD_ROLES; "
                f"decide whether it changes the compiled plan and add it"))
    for f in sorted(set(SPEC_FIELD_ROLES) - set(fields)):
        out.append(Finding(
            rel, line, "fingerprint-coverage",
            f"SPEC_FIELD_ROLES names {f!r} which is no longer a "
            f"ScenarioSpec field"))
    keyed = _key_fn_spec_attrs(cache_path)
    crel = os.path.join(os.path.basename(root), "scenario", "cache.py")
    for f in sorted(fn for fn, role in SPEC_FIELD_ROLES.items()
                    if role == "plan" and fn in fields):
        if f not in keyed:
            out.append(Finding(
                crel, 1, "fingerprint-coverage",
                f"plan-identity field spec.{f} is not folded into "
                f"overlay_fingerprint/policy_key — cache entries can "
                f"collide across values of {f!r}"))
    return out


def lint_file(path: str, rel: Optional[str] = None) -> List[Finding]:
    """All per-file rule findings for one Python source file."""
    rel = (rel or path).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    aliases = _module_aliases(tree)
    out: List[Finding] = []
    _check_rng(tree, rel, aliases, out)
    _check_wall_clock(tree, rel, aliases, out)
    _check_fingerprint_order(tree, rel, out)
    return out


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` plus the cross-file fingerprint
    coverage check. Paths in findings are relative to ``root``'s parent
    (``src/repro/... -> repro/...``)."""
    root = os.path.abspath(root)
    base = os.path.dirname(root)
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            findings.extend(lint_file(path, os.path.relpath(path, base)))
    findings.extend(check_fingerprint_coverage(root))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def load_allowlist(path: str) -> List[Tuple[str, str, str]]:
    """Parse allowlist lines: ``<path-suffix> <rule> <detail-substring>``
    (blank lines and ``#`` comments skipped)."""
    entries: List[Tuple[str, str, str]] = []
    with open(path, "r", encoding="utf-8") as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split(None, 2)
            if len(parts) != 3:
                raise ValueError(
                    f"{path}: malformed allowlist line {line!r} "
                    f"(want: <path-suffix> <rule> <detail-substring>)")
            entries.append((parts[0], parts[1], parts[2]))
    return entries


def filter_allowed(findings: Sequence[Finding],
                   allow: Sequence[Tuple[str, str, str]]) -> List[Finding]:
    """Drop findings matched by an allowlist entry."""
    out = []
    for f in findings:
        if not any(f.path.endswith(suffix) and f.rule == rule
                   and sub in f.detail
                   for suffix, rule, sub in allow):
            out.append(f)
    return out
