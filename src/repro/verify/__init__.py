"""Static plan verification: prove schedule safety, progress, and byte
conservation *before* anything runs.

The MST + coloring efficiency claim of the paper rests on the compiled
schedule being conflict-free; with five executors, an incremental
replanner and an overlay optimizer all producing/consuming the same plan
IR, that property deserves a proof at counting speed rather than a
simulator run and a hopeful assertion. This package analyzes a frozen
plan — one emit/commit walk, no executor — and returns a
:class:`~repro.verify.invariants.Certificate` naming exactly which
invariant classes were proven (:data:`~repro.verify.invariants.
INVARIANT_CLASSES`) and which were skipped, with reasons.

Entry points:

* :func:`verify_policy` / :func:`verify_plan` — one plan, one certificate.
* :func:`verify_scenario_plans` — every membership epoch of a declared
  :class:`~repro.scenario.spec.ScenarioSpec`, sharing (and warming) the
  same :class:`~repro.scenario.cache.PlanCache` the executors use; a plan
  verified once is never re-verified (the cache's ``verified`` stage).
* :func:`verify_result` — recheck an executed
  :class:`~repro.scenario.spec.ScenarioResult`'s byte accounting against
  the static wire model.
* ``run_scenario(spec, verify="strict"|"warn"|"off")`` — the runner calls
  :func:`verify_scenario_plans` first (sharing the cache), so a violating
  plan never reaches an executor. ``"off"`` (the default) does not even
  import this package.
* ``python -m repro.verify --all`` — the CI conformance gate over every
  registry scenario and sweep cell; ``--lint`` runs the determinism lint
  (:mod:`repro.verify.lint`).
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional, Tuple

from .invariants import (
    INVARIANT_CLASSES,
    Certificate,
    PlanFacts,
    VerificationError,
    _Skip,
    admission_edges,
    check_admission_acyclic,
    check_admission_schedule,
    check_capacity,
    check_color_discipline,
    check_conservation,
    check_degree_cap,
    check_edges_in_graph,
    check_half_duplex,
    check_node_range,
    check_progress,
    check_proper_coloring,
    check_report_conservation,
    recompute_wire_mb,
)

VERIFY_MODES = ("off", "warn", "strict")

__all__ = [
    "Certificate", "INVARIANT_CLASSES", "PlanFacts", "VERIFY_MODES",
    "VerificationError", "VerificationWarning", "admission_edges",
    "check_admission_acyclic", "check_admission_schedule", "verify_facts",
    "verify_plan", "verify_policy", "verify_result",
    "verify_scenario_plans",
]


class VerificationWarning(UserWarning):
    """``mode="warn"``: a plan failed verification but execution proceeds."""


def verify_facts(facts: PlanFacts, network=None,
                 payload_mb: Optional[float] = None, codec=None,
                 rounds: int = 1, max_staleness: int = 0,
                 plan=None,
                 expected_stats: Optional[Dict[str, float]] = None
                 ) -> Certificate:
    """Run every applicable invariant checker over one frozen plan.

    Raises :class:`VerificationError` on the first violation (checkers run
    in the documented order, so rejection tests can rely on which
    invariant names a given defect); inapplicable checks are recorded in
    ``Certificate.skipped`` with the reason, never silently dropped.
    """
    cert = Certificate(kind=facts.kind, n=facts.n, n_slots=facts.n_slots,
                       transmissions=facts.transmissions)

    def ran(name: str) -> None:
        cert.invariants.append(name)

    check_node_range(facts)
    ran("structure/node-range")

    if facts.graph is not None:
        check_edges_in_graph(facts)
        ran("structure/edges-in-graph")
    elif facts.kind == "broadcast_exchange":
        cert.skipped["structure/edges-in-graph"] = (
            "broadcast runs on the complete graph (no edge universe)")
    else:
        cert.skipped["structure/edges-in-graph"] = (
            "plan carries no scheduled graph")

    colored = any(rec.color >= 0 for rec in facts.slots)
    if not colored:
        reason = "uncolored slot-synchronous schedule"
        for name in ("schedule/half-duplex", "schedule/color-discipline",
                     "schedule/proper-coloring"):
            cert.skipped[name] = reason
    elif facts.colors is None:
        check_half_duplex(facts)
        ran("schedule/half-duplex")
        reason = "no color assignment attached to the plan"
        cert.skipped["schedule/color-discipline"] = reason
        cert.skipped["schedule/proper-coloring"] = reason
    else:
        check_half_duplex(facts)
        ran("schedule/half-duplex")
        check_color_discipline(facts)
        ran("schedule/color-discipline")
        check_proper_coloring(facts)
        ran("schedule/proper-coloring")

    check_degree_cap(facts)
    ran("schedule/degree-cap")

    if network is not None:
        cert.max_link_flows = check_capacity(facts, network)
        ran("capacity/admissible")
    else:
        cert.skipped["capacity/admissible"] = (
            "no compiled underlay (counting-only path)")

    try:
        cert.completion_slot, cert.segment_completion = check_progress(facts)
    except _Skip as skip:
        cert.skipped["progress/causal-possession"] = str(skip)
        cert.skipped["progress/completeness"] = str(skip)
    else:
        ran("progress/causal-possession")
        ran("progress/completeness")

    check_admission_schedule(rounds, max_staleness)
    ran("staleness/window-negative")
    ran("staleness/admission-acyclic")

    if payload_mb is not None:
        cert.wire_mb = check_conservation(
            facts, payload_mb, codec, plan=plan,
            expected_stats=expected_stats)
        ran("conservation/bytes-on-wire")
    else:
        cert.skipped["conservation/bytes-on-wire"] = (
            "no payload size declared")
    return cert


def verify_policy(policy, *, network=None, payload_mb: Optional[float] = None,
                  codec=None, rounds: int = 1, max_staleness: int = 0,
                  expected_stats: Optional[Dict[str, float]] = None
                  ) -> Certificate:
    """Freeze a live :class:`~repro.core.plan.CommPolicy` (one emit/commit
    walk; the policy is reset before and after) and verify it."""
    facts = PlanFacts.from_policy(policy)
    return verify_facts(facts, network=network, payload_mb=payload_mb,
                        codec=codec, rounds=rounds,
                        max_staleness=max_staleness,
                        expected_stats=expected_stats)


def verify_plan(plan, *, graph=None, network=None,
                payload_mb: Optional[float] = None, codec=None,
                rounds: int = 1, max_staleness: int = 0) -> Certificate:
    """Verify a compiled :class:`~repro.core.plan.SlotPlan`. ``graph``
    restores the edge universe a compiled plan no longer carries."""
    facts = PlanFacts.from_plan(plan, graph=graph)
    return verify_facts(facts, network=network, payload_mb=payload_mb,
                        codec=codec, rounds=rounds,
                        max_staleness=max_staleness, plan=plan)


def _verified_key(spec, members: Tuple[int, ...]) -> Tuple[Any, ...]:
    from ..core.network import underlay_fingerprint
    from ..scenario.cache import policy_key

    return (policy_key(spec, members), str(spec.payload), spec.codec,
            underlay_fingerprint(spec.testbed(), spec.n), spec.rounds,
            spec.max_staleness)


def _epoch_certificate(spec, members: Tuple[int, ...], mod, overlay,
                       cache) -> Certificate:
    """Build + verify one membership epoch's plan, through the same cache
    stages the executors use (so verification *warms* the cache: the
    executor that runs next gets policy/measure hits, not rebuilds)."""
    from ..core.network import as_compiled_network
    from ..core.sparse import CSRGraph
    from ..scenario.executors import _member_testbed

    sparse = isinstance(overlay, CSRGraph)
    if sparse:
        policy = cache.sparse_policy(spec, members, overlay)
    else:
        policy = cache.policy(spec, members, lambda: mod.build_graph()[0])
    network = None
    if not sparse:
        try:
            network = as_compiled_network(_member_testbed(spec, members))
        except TypeError:
            network = None  # non-compilable underlay: capacity check skipped
    stats = cache.measure(spec, members, pol=policy)
    return verify_policy(
        policy, network=network, payload_mb=spec.payload_mb(),
        codec=spec.codec_obj(), rounds=spec.rounds,
        max_staleness=spec.max_staleness, expected_stats=stats)


def verify_scenario_plans(spec, plan_cache=None,
                          mode: str = "strict") -> Dict[str, Any]:
    """Statically verify every membership epoch a scenario will schedule.

    Walks the same moderator lifecycle the executors drive
    (:func:`~repro.scenario.executors.membership_rounds`), builds each
    unique epoch's policy through the shared plan cache, and verifies it
    once — the cache's ``verified`` stage memoizes certificates by (plan
    identity, payload, codec, underlay, rounds, staleness), so re-running
    a scenario (or a sweep sharing plans across cells) never re-verifies.

    ``mode="strict"`` raises :class:`VerificationError`; ``mode="warn"``
    downgrades it to a :class:`VerificationWarning` and reports
    ``ok=False``. Returns a summary dict with per-epoch certificates.
    """
    if mode not in ("warn", "strict"):
        raise ValueError(f"verify mode must be 'warn' or 'strict', "
                         f"got {mode!r}")
    from .. import obs
    from ..scenario.cache import PlanCache
    from ..scenario.executors import membership_rounds

    spec.validate()
    cache = plan_cache if plan_cache is not None else PlanCache()
    rec = obs.get()
    overlay = cache.overlay(spec)
    certs: List[Certificate] = []
    epochs = 0
    seen: set = set()
    error: Optional[VerificationError] = None
    try:
        for r, mod, members, _applied in membership_rounds(spec, overlay):
            mt = tuple(members)
            if mt in seen:
                continue
            seen.add(mt)
            epochs += 1
            key = _verified_key(spec, mt)

            def build(mt=mt, mod=mod) -> Certificate:
                if rec.enabled:
                    with rec.span(f"verify {spec.name}", cat="verify",
                                  track="verify", scenario=spec.name,
                                  members=len(mt)):
                        cert = _epoch_certificate(spec, mt, mod, overlay,
                                                  cache)
                    rec.count("verify.plans", 1)
                    rec.count("verify.invariants", len(cert.invariants))
                else:
                    cert = _epoch_certificate(spec, mt, mod, overlay, cache)
                return cert

            certs.append(cache.verified(key, build))
    except VerificationError as exc:
        if mode == "strict":
            raise
        error = exc
        warnings.warn(
            f"scenario {spec.name!r} failed static verification: {exc}",
            VerificationWarning, stacklevel=2)
    return {
        "scenario": spec.name,
        "mode": mode,
        "ok": error is None,
        "error": None if error is None else str(error),
        "invariant": None if error is None else error.invariant,
        "epochs": epochs,
        "certificates": certs,
    }


def verify_result(spec, result, plan_cache=None) -> int:
    """Recheck an executed scenario's per-round byte accounting against the
    static wire model (the conservation invariant, applied to what an
    executor *reported* rather than what the plan schedules). Returns the
    number of rounds checked; raises :class:`VerificationError` on any
    disagreement."""
    from ..core.sparse import CSRGraph
    from ..scenario.cache import PlanCache
    from ..scenario.executors import membership_rounds

    cache = plan_cache if plan_cache is not None else PlanCache()
    overlay = cache.overlay(spec)
    payload_mb = spec.payload_mb()
    codec = spec.codec_obj()
    by_round = {rep.round: rep for rep in result.rounds}
    facts_by_epoch: Dict[Tuple[int, ...], PlanFacts] = {}
    checked = 0
    for r, mod, members, _applied in membership_rounds(spec, overlay):
        mt = tuple(members)
        facts = facts_by_epoch.get(mt)
        if facts is None:
            if isinstance(overlay, CSRGraph):
                policy = cache.sparse_policy(spec, mt, overlay)
            else:
                policy = cache.policy(spec, mt,
                                      lambda: mod.build_graph()[0])
            facts = facts_by_epoch[mt] = PlanFacts.from_policy(policy)
        rep = by_round.get(r)
        if rep is None:
            continue
        check_report_conservation(facts, payload_mb, codec, rep)
        checked += 1
    return checked
