"""Adaptive overlay optimization: analytic-cost-guided topology search.

The subsystem the ROADMAP names as "adaptive / learned overlays using the
analytic model as a cost oracle": a seeded, deterministic edit-based search
over overlay topologies where every candidate is scored by the closed-form
timing/throughput oracle (:mod:`repro.core.network`) via exact incremental
plan maintenance — never a full plan rebuild, never a simulator run in the
inner loop. See DESIGN.md §16.
"""
from .membership import membership_descent
from .objective import (
    OBJECTIVES,
    EvalContext,
    Objective,
    context_for_scenario,
    make_objective,
)
from .search import (
    MOVE_KINDS,
    STRATEGIES,
    OptimizeResult,
    OptimizerSpec,
    optimize_for_scenario,
    optimize_overlay,
    reoptimize,
)
from .state import Candidate, SearchState

__all__ = [
    "MOVE_KINDS",
    "OBJECTIVES",
    "STRATEGIES",
    "Candidate",
    "EvalContext",
    "Objective",
    "OptimizeResult",
    "OptimizerSpec",
    "SearchState",
    "context_for_scenario",
    "make_objective",
    "membership_descent",
    "optimize_for_scenario",
    "optimize_overlay",
    "reoptimize",
]
