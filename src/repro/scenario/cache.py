"""Cross-cell plan cache: MST + coloring + policy computed once per unique
member subgraph, shared by every executor and by :func:`run_sweep`.

A sweep is a grid of :class:`~repro.scenario.spec.ScenarioSpec` cells that
mostly *share* their communication structure: a payload x codec grid over
one topology has 32 cells but exactly one MST/coloring/policy, and even a
topology x protocol grid only has as many unique plans as unique
``(member set, overlay, protocol, n_segments)`` combinations. Before the
sweep API every cell recomputed all of it.

:class:`PlanCache` memoizes the deterministic stages:

=============  ==========================================================
stage          key
=============  ==========================================================
overlay graph  overlay fingerprint (TopologySpec fields | matrix bytes)
member         (overlay, member set) — the moderator-built dense subgraph
subgraph
policy         (overlay, members, protocol, n_segments, mst/coloring
               algorithm, first color) — ``make_policy`` output
measure        policy key — ``measure_policy`` slot/transmission counts
slots          policy key — per-slot (src, dst) arrays for the event engine
timing         (policy key, underlay fingerprint) — the analytic
               :class:`~repro.core.network.TimingProfile` (payload-
               independent; evaluated per wire size)
member plan    (overlay, members, mst/coloring algorithm) — the sparse
               :class:`~repro.core.replan.MemberPlan`; misses repair the
               previous epoch's plan incrementally when one exists
=============  ==========================================================

Cached :class:`~repro.core.plan.CommPolicy` objects are stateful but every
consumer (``measure_policy``, ``simulate_policy``, ``GossipEngine``) resets
them before use, so sequential sharing is safe; results are bit-identical
to a cold build (pinned by ``tests/test_sweep.py``). Hit/miss counters per
stage make cache effectiveness a first-class, testable metric.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

import numpy as np

from ..core.graph import MST_ALGORITHMS, Graph, TopologySpec, color_graph
from ..core.network import TimingProfile, _field_tuple, underlay_fingerprint
from ..core.plan import CommPolicy, make_policy, measure_policy
from ..core.replan import MemberPlan, SparsePlanner
from ..core.sparse import CSRGraph

if TYPE_CHECKING:  # pragma: no cover
    from .spec import ScenarioSpec

PolicyKey = Tuple[Any, ...]


def overlay_fingerprint(spec: "ScenarioSpec") -> Tuple[Any, ...]:
    """A hashable identity for a scenario's declared overlay.

    A :class:`TopologySpec` is identified by its field values (generation is
    deterministic given the spec); an explicit cost matrix by its exact
    bytes, so two numerically identical matrices share cache entries.
    (Flat ``_field_tuple`` rather than ``dataclasses.astuple`` — the
    deepcopy recursion inside ``astuple`` dominated sweep-grid key
    building.)
    """
    ov = spec.overlay
    if isinstance(ov, TopologySpec):
        return ("topo",) + _field_tuple(ov)
    a = np.asarray(ov, dtype=np.float64)
    return ("matrix", a.shape, a.tobytes())


def policy_key(spec: "ScenarioSpec",
               members: Tuple[int, ...]) -> PolicyKey:
    """The cache identity of one membership epoch's communication plan."""
    return (overlay_fingerprint(spec), members, spec.protocol,
            spec.n_segments, spec.mst_algorithm, spec.coloring_algorithm)


class PlanCache:
    """Memoizes overlay -> subgraph -> policy -> counting stats.

    One instance may span many :func:`run_scenario` calls (that is the point
    — :func:`run_sweep` threads one cache through every cell); a fresh
    instance per call reproduces the historical cold-build behaviour
    exactly.
    """

    def __init__(self) -> None:
        self._overlays: Dict[Tuple[Any, ...], Graph] = {}
        self._subgraphs: Dict[Tuple[Any, ...], Graph] = {}
        self._policies: Dict[PolicyKey, CommPolicy] = {}
        self._measures: Dict[PolicyKey, Dict[str, float]] = {}
        self._trajectories: Dict[Tuple[Any, ...], list] = {}
        self._slots: Dict[PolicyKey, list] = {}
        self._timings: Dict[Tuple[Any, ...], TimingProfile] = {}
        self._member_plans: Dict[Tuple[Any, ...], MemberPlan] = {}
        self._planners: Dict[Tuple[Any, ...], SparsePlanner] = {}
        self._latest_plan: Dict[Tuple[Any, ...], MemberPlan] = {}
        self.counters: Dict[str, int] = {
            "overlay_hits": 0, "overlay_misses": 0,
            "subgraph_hits": 0, "subgraph_misses": 0,
            "policy_hits": 0, "policy_misses": 0,
            "measure_hits": 0, "measure_misses": 0,
            "slots_hits": 0, "slots_misses": 0,
            "trajectory_hits": 0, "trajectory_misses": 0,
            "timing_hits": 0, "timing_misses": 0,
            "replan_hits": 0, "replan_misses": 0,
            "replan_incremental": 0, "replan_full": 0,
        }

    # -- stages --------------------------------------------------------------
    def overlay(self, spec: "ScenarioSpec") -> Graph:
        key = overlay_fingerprint(spec)
        g = self._overlays.get(key)
        if g is None:
            self.counters["overlay_misses"] += 1
            g = self._overlays[key] = spec.overlay_graph()
        else:
            self.counters["overlay_hits"] += 1
        return g

    def subgraph(self, spec: "ScenarioSpec", members: Tuple[int, ...],
                 build) -> Graph:
        """The moderator-built dense member subgraph; ``build()`` computes it
        on a miss (it is a pure function of (overlay, member set): reports
        are filed symmetrically from the overlay's cost matrix)."""
        key = (overlay_fingerprint(spec), members)
        g = self._subgraphs.get(key)
        if g is None:
            self.counters["subgraph_misses"] += 1
            g = self._subgraphs[key] = build()
        else:
            self.counters["subgraph_hits"] += 1
        return g

    def policy(self, spec: "ScenarioSpec", members: Tuple[int, ...],
               build_subgraph) -> CommPolicy:
        """``make_policy`` over the member subgraph, computed once per key."""
        key = policy_key(spec, members)
        pol = self._policies.get(key)
        if pol is None:
            self.counters["policy_misses"] += 1
            g_sub = self.subgraph(spec, members, build_subgraph)
            pol = self._policies[key] = make_policy(
                spec.protocol, g_sub,
                mst_algorithm=spec.mst_algorithm,
                coloring_algorithm=spec.coloring_algorithm,
                n_segments=spec.n_segments)
        else:
            self.counters["policy_hits"] += 1
        return pol

    def measure(self, spec: "ScenarioSpec", members: Tuple[int, ...],
                pol: Optional[CommPolicy] = None,
                stats: Optional[Dict[str, float]] = None) -> Dict[str, float]:
        """Cached ``measure_policy`` counts for one epoch's policy.

        ``stats`` seeds a miss with already-computed counts (e.g. a
        :meth:`~repro.core.network.TimingProfile.measure_stats` from the
        timing walk) so consumers needing timing *and* counts walk the
        policy once."""
        key = policy_key(spec, members)
        cached = self._measures.get(key)
        if cached is None:
            self.counters["measure_misses"] += 1
            if stats is not None:
                cached = self._measures[key] = stats
            elif pol is not None:
                cached = self._measures[key] = measure_policy(pol)
            else:
                raise ValueError("measure miss needs the policy to count")
        else:
            self.counters["measure_hits"] += 1
        return cached

    def slots(self, spec: "ScenarioSpec", members: Tuple[int, ...],
              pol: CommPolicy) -> list:
        """Cached per-slot ``(src, dst)`` arrays for the event engine
        (:func:`repro.core.events.policy_slots`). One policy walk per unique
        plan — every round of an epoch, and every cell sharing the plan,
        replays the same arrays."""
        from ..core.events import policy_slots

        key = policy_key(spec, members)
        cached = self._slots.get(key)
        if cached is None:
            self.counters["slots_misses"] += 1
            cached = self._slots[key] = policy_slots(pol)
        else:
            self.counters["slots_hits"] += 1
        return cached

    def timing(self, spec: "ScenarioSpec", members: Tuple[int, ...],
               underlay, build) -> TimingProfile:
        """Cached analytic :class:`~repro.core.network.TimingProfile` for one
        epoch's plan on one underlay. The profile is payload-independent —
        a payload x codec grid over one plan shares a single profile and
        only re-evaluates the closed form per wire size. ``underlay`` is the
        member-masked underlay spec the profile was (or will be) built on;
        ``build()`` walks the policy on a miss."""
        key = (policy_key(spec, members),
               underlay_fingerprint(underlay, spec.n))
        profile = self._timings.get(key)
        if profile is None:
            self.counters["timing_misses"] += 1
            profile = self._timings[key] = build()
        else:
            self.counters["timing_hits"] += 1
        return profile

    def member_plan(self, spec: "ScenarioSpec", members: Tuple[int, ...],
                    overlay: CSRGraph) -> MemberPlan:
        """Sparse MST + Jones–Plassmann plan for one membership epoch.

        This is the incremental-replanning stage: one
        :class:`~repro.core.replan.SparsePlanner` lives per (overlay,
        algorithms) key, and the *latest* plan built on it seeds a churn
        repair (``replan``) instead of a from-scratch build whenever the
        epoch's member set is new. ``replan_incremental`` vs
        ``replan_full`` counts how often the repair path actually ran —
        the metric behind the ≥5× churn-replan floor in
        ``benchmarks/planner_bench.py``.
        """
        if spec.mst_algorithm not in MST_ALGORITHMS:
            raise ValueError(f"unknown MST algorithm {spec.mst_algorithm!r}")
        key = (overlay_fingerprint(spec), members,
               spec.mst_algorithm, spec.coloring_algorithm)
        plan = self._member_plans.get(key)
        if plan is not None:
            self.counters["replan_hits"] += 1
            return plan
        self.counters["replan_misses"] += 1
        pkey = key[:1] + key[2:]
        planner = self._planners.get(pkey)
        if planner is None:
            planner = self._planners[pkey] = SparsePlanner(overlay)
        prev = self._latest_plan.get(pkey)
        if prev is not None:
            plan = planner.replan(prev, members)
            self.counters["replan_incremental"] += 1
        else:
            plan = planner.plan(members)
            self.counters["replan_full"] += 1
        self._member_plans[key] = self._latest_plan[pkey] = plan
        return plan

    def sparse_policy(self, spec: "ScenarioSpec", members: Tuple[int, ...],
                      overlay: CSRGraph) -> CommPolicy:
        """``make_policy`` over a sparse overlay — no dense subgraph is ever
        materialized. MST protocols consume the :meth:`member_plan` tree and
        colors (recoloring with the requested algorithm when it is not the
        planner's native Jones–Plassmann); flooding runs on the member-
        induced CSR subgraph directly."""
        key = policy_key(spec, members)
        pol = self._policies.get(key)
        if pol is not None:
            self.counters["policy_hits"] += 1
            return pol
        self.counters["policy_misses"] += 1
        if spec.protocol in ("flooding", "broadcast", "broadcast_exchange"):
            pol = make_policy(spec.protocol, overlay.subgraph(members))
        else:
            plan = self.member_plan(spec, members, overlay)
            mst, colors = plan.member_mst()
            if spec.coloring_algorithm != "jones_plassmann":
                colors = color_graph(mst, spec.coloring_algorithm)
            pol = make_policy(spec.protocol, mst, mst=mst, colors=colors,
                              n_segments=spec.n_segments)
        self._policies[key] = pol
        return pol

    def trajectory(self, spec: "ScenarioSpec", build) -> list:
        """Cached membership trajectory: ``(round, moderator, members,
        applied_churn)`` per round. Depends only on (overlay, rounds, churn)
        — not on protocol or payload — so a payload x codec grid replays the
        moderator lifecycle once. ``build()`` must also file each epoch's
        member subgraph via :meth:`subgraph` so hits never need a moderator.
        """
        key = (overlay_fingerprint(spec), spec.rounds, spec.churn)
        traj = self._trajectories.get(key)
        if traj is None:
            self.counters["trajectory_misses"] += 1
            traj = self._trajectories[key] = build()
        else:
            self.counters["trajectory_hits"] += 1
        return traj

    # -- accounting ----------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        out = dict(self.counters)
        out["unique_overlays"] = len(self._overlays)
        out["unique_subgraphs"] = len(self._subgraphs)
        out["unique_policies"] = len(self._policies)
        out["unique_timing_profiles"] = len(self._timings)
        out["unique_member_plans"] = len(self._member_plans)
        return out
