"""Static-verification CLI: ``python -m repro.verify``.

Examples (from the repo root, ``PYTHONPATH=src``)::

  python -m repro.verify --scenario paper_table3
  python -m repro.verify --sweep table3_full
  python -m repro.verify --all          # CI conformance gate: every
                                        # registry scenario + gated sweeps
  python -m repro.verify --all --lint   # plus the determinism lint

One :class:`~repro.scenario.cache.PlanCache` is shared across everything
verified in a run, so sweep cells sharing a plan verify it exactly once
(the ``verified`` stage); the exit status is non-zero when any plan fails
or any lint finding remains.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

from . import VerificationError, verify_scenario_plans

#: the sweeps the CI conformance gate verifies cell-by-cell
GATED_SWEEPS = ("table3_full", "async_vs_sync", "optimized_vs_mst")


def _verify_one(label: str, spec, cache, mode: str) -> bool:
    t0 = time.perf_counter()
    try:
        out = verify_scenario_plans(spec, plan_cache=cache, mode=mode)
    except VerificationError as exc:
        print(f"  {label:34s} FAIL {exc}")
        return False
    dt = time.perf_counter() - t0
    certs = out["certificates"]
    n_inv = max((len(c.invariants) for c in certs), default=0)
    if out["ok"]:
        print(f"  {label:34s} verified ✓ ({n_inv} invariants, "
              f"{out['epochs']} epoch{'s' if out['epochs'] != 1 else ''}, "
              f"{dt:.2f}s)")
        return True
    print(f"  {label:34s} FAIL [{out['invariant']}] {out['error']}")
    return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify", description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", nargs="*", metavar="NAME", default=[],
                    help="registry scenario name(s) to verify")
    ap.add_argument("--sweep", nargs="*", metavar="NAME", default=[],
                    help="registry sweep name(s); every cell is verified")
    ap.add_argument("--all", action="store_true",
                    help=f"every registry scenario + the gated sweeps "
                         f"{GATED_SWEEPS}")
    ap.add_argument("--lint", action="store_true",
                    help="also run the determinism lint over src/repro")
    ap.add_argument("--mode", choices=("strict", "warn"), default="warn",
                    help="'warn' reports all failures; 'strict' raises on "
                         "the first (default: warn, still exit 1 on any)")
    args = ap.parse_args(argv)

    from ..scenario import scenarios
    from ..scenario.cache import PlanCache

    scenario_names: List[str] = list(args.scenario)
    sweep_names: List[str] = list(args.sweep)
    if args.all:
        scenario_names.extend(
            n for n in scenarios.names() if n not in scenario_names)
        sweep_names.extend(
            n for n in GATED_SWEEPS if n not in sweep_names)
    if not (scenario_names or sweep_names or args.lint):
        ap.error("nothing to do: pass --scenario/--sweep/--all/--lint")

    cache = PlanCache()
    failures = 0
    if scenario_names:
        print("scenarios:")
        for name in scenario_names:
            if not _verify_one(name, scenarios.get(name), cache, args.mode):
                failures += 1
    for sweep_name in sweep_names:
        sweep = scenarios.get_sweep(sweep_name)
        cells = sweep.cells()
        print(f"sweep {sweep_name} ({len(cells)} cells):")
        for cell in cells:
            coords = ",".join(f"{k}={v}" for k, v in cell.coords.items())
            if not _verify_one(f"[{cell.index}] {coords}"[:34], cell.spec,
                               cache, args.mode):
                failures += 1
    if scenario_names or sweep_names:
        stats = cache.stats()
        print(f"plans verified: {stats['verified_misses']} "
              f"(re-use hits: {stats['verified_hits']})")

    if args.lint:
        import os

        from .lint import filter_allowed, lint_tree, load_allowlist

        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        findings = lint_tree(root)
        allowlist = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(root))), "tools", "lint_allowlist.txt")
        if os.path.exists(allowlist):
            findings = filter_allowed(findings, load_allowlist(allowlist))
        for f in findings:
            print(f)
        print(f"lint: {len(findings)} finding(s)")
        failures += len(findings)

    if failures:
        print(f"\nverify: {failures} failure(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
