"""Network-model API: pluggable underlays with analytic round timing.

The paper's headline result is *transfer time* — round-time reductions of up
to 4.4x that come entirely from how the gossip schedule interacts with the
physical network (Tables III–V). Before this module the underlay was a single
hardcoded shape: :class:`repro.core.netsim.TestbedSpec` assumed one implicit
full mesh of routers, a uniform access rate for every device, and a 0-or-2
router-hop latency rule baked into ``latency()``. This module gives the
underlay the same treatment the overlay, protocol, codec and sweep layers
already received — a declarative, pluggable API:

* :class:`NetworkSpec` **declares** a physical network: an arbitrary router
  graph (``mesh`` / ``line`` / ``star`` or explicit edges) with
  shortest-path routing, per-node access rates (uniform or heterogeneous,
  drawn deterministically from a seed), trunk capacity, latency constants
  and the goodput-collapse model;
* :meth:`NetworkSpec.build` **compiles** it into a :class:`CompiledNetwork`
  — the runtime *network model* every consumer routes through:
  ``links_for`` (route → sequence of links), ``capacity`` (per-link),
  ``latency`` (per-path), plus the contention constants. The fluid
  simulator (:mod:`repro.core.netsim`) and the analytic timing model below
  both interpret this one interface, so they can never disagree about the
  network;
* :data:`NETWORK_PRESETS` names reusable shapes (``paper_lan`` — the
  default 3-subnet testbed, ``wan``, ``edge``, ``congested``);
* :func:`estimate_timing` is the **vectorized analytic timing model**: a
  closed-form per-slot bottleneck + contention formula over a compiled
  communication plan that reproduces :class:`~repro.core.netsim.
  FluidSimulator` round times within the tolerance contract below at
  counting speed — this is what lets the ``plan`` executor report round
  times for a whole sweep grid without running the fluid simulation per
  cell.

Tolerance contract (pinned by ``tests/test_network.py`` and recorded per
preset in ``BENCH_underlay.json``): for slot-synchronous policies
(dissemination, segmented, exchanges, tree) the analytic estimate tracks the
fluid simulator within ±15% on every registry scenario and preset; for the
event-driven flooding baseline the estimate uses an effective-concurrency
approximation that holds ±15% on the registry/preset set and degrades to
roughly ±25% on hub-heavy overlays (Barabási–Albert at large payloads) —
the fluid simulator remains the reference where that tail matters.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .graph import Graph, subnet_of

# A physical link: ("access-up"/"access-down", node, -1) or ("trunk", r1, r2)
# with r1 < r2. Shared with (and re-exported by) repro.core.netsim.
LinkId = Tuple[str, int, int]

ROUTER_KINDS = ("mesh", "line", "star")


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------


@dataclass
class NetworkSpec:
    """A declared physical underlay: devices behind a routed trunk fabric.

    Every field is plain data, so specs serialize, sweep (``underlay=`` is a
    :class:`~repro.scenario.spec.ScenarioSpec` field and therefore a sweep
    axis) and fingerprint for the plan cache. :meth:`build` compiles the
    spec into the runtime :class:`CompiledNetwork`.
    """

    name: str = "custom"
    n: int = 10
    n_subnets: int = 3
    # Router fabric: a named shape over ``n_subnets`` routers, or explicit
    # undirected router edges. Transfers follow shortest paths (hop count,
    # deterministic low-index tie-break) across the fabric.
    router_kind: str = "mesh"  # mesh | line | star
    router_edges: Optional[Tuple[Tuple[int, int], ...]] = None
    # Access links. ``access_range`` switches on per-node heterogeneity:
    # rates are drawn uniformly from the range, deterministically from
    # ``het_seed`` and the *physical* node id (stable under churn masking).
    access_mbps: float = 12.0
    access_range: Optional[Tuple[float, float]] = None
    het_seed: int = 0
    trunk_mbps: float = 30.0
    base_latency_s: float = 0.15  # per-transfer protocol overhead (FTP setup)
    hop_latency_s: float = 0.35  # extra latency per router hop on the path
    per_flow_cap_mbps: float = 11.0  # single-flow application ceiling
    # Goodput collapse under contention (same model as TestbedSpec): with k
    # flows on a link, capacity shrinks by 1/(1 + gamma * max(0, k - k0));
    # gamma additionally scales with sqrt(size / collapse_ref_mb).
    collapse_gamma: float = 0.05
    collapse_k0: int = 3
    collapse_ref_mb: float = 30.0
    # Churn masking (scenario runner): ``node_ids[i]`` is the physical id of
    # dense index i, ``phys_n`` the physical device count — heterogeneous
    # rates and subnet routing follow the physical layout.
    node_ids: Optional[Tuple[int, ...]] = None
    phys_n: Optional[int] = None

    def __post_init__(self) -> None:
        if self.router_edges is not None:
            # fully normalized (low-high, deduped, sorted): equivalent
            # spellings compare equal and share cache fingerprints
            self.router_edges = tuple(sorted(
                {(min(a, b), max(a, b)) for a, b in self.router_edges}))
        if self.access_range is not None:
            self.access_range = tuple(self.access_range)  # type: ignore

    # -- validation ----------------------------------------------------------
    def validate(self) -> "NetworkSpec":
        if self.n < 1:
            raise ValueError("a network needs at least one node")
        if self.n_subnets < 1:
            raise ValueError("n_subnets must be >= 1")
        if self.router_edges is None and self.router_kind not in ROUTER_KINDS:
            raise ValueError(
                f"unknown router_kind {self.router_kind!r}; "
                f"known: {ROUTER_KINDS} (or pass explicit router_edges)")
        if self.router_edges is not None:
            bad = [e for e in self.router_edges
                   if not all(0 <= r < self.n_subnets for r in e)]
            if bad:
                raise ValueError(
                    f"router_edges {bad} name routers outside "
                    f"[0, {self.n_subnets})")
        if self.access_range is not None:
            lo, hi = self.access_range
            if not (0 < lo <= hi):
                raise ValueError(f"bad access_range {self.access_range}")
        if self.access_mbps <= 0 or self.trunk_mbps <= 0:
            raise ValueError("link capacities must be positive")
        return self

    # -- derived views -------------------------------------------------------
    def subnet(self, node: int) -> int:
        """Dense node index -> router subnet (physical layout under churn)."""
        if self.node_ids is not None:
            return subnet_of(self.node_ids[node], self.phys_n or self.n,
                             self.n_subnets)
        return subnet_of(node, self.n, self.n_subnets)

    def masked(self, members: Sequence[int]) -> "NetworkSpec":
        """The network restricted to ``members`` (dense reindexing), keeping
        the physical subnet layout and per-node heterogeneity."""
        return mask_underlay(self, members)

    def build(self) -> "CompiledNetwork":
        """Compile to the runtime network model (routes + rate tables)."""
        return CompiledNetwork(self.validate())

    # -- identity ------------------------------------------------------------
    def fingerprint(self) -> Tuple[Any, ...]:
        """Hashable identity (plan-cache key component)."""
        return ("network",) + _field_tuple(self)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["type"] = "NetworkSpec"
        return d


def mask_underlay(spec, members: Sequence[int]):
    """One underlay spec restricted to the healthy ``members`` — THE churn
    masking rule (dense reindexing; ``phys_n`` pins the physical layout so
    subnet routing and seeded per-node rates survive the renumbering).
    Shared by :meth:`NetworkSpec.masked` and
    :meth:`repro.core.netsim.TestbedSpec.masked` so the two underlay
    flavours cannot drift apart."""
    return dataclasses.replace(
        spec, n=len(members), node_ids=tuple(members),
        phys_n=spec.phys_n or spec.n)


def router_graph_edges(kind: str, n_subnets: int) -> Tuple[Tuple[int, int], ...]:
    """The undirected router edges of a named fabric shape.

    ``mesh`` — every router pair directly trunked (the paper's implicit
    assumption); ``line`` — routers chained 0-1-2-…; ``star`` — router 0 is
    the hub every other router trunks into (campus/WAN core).
    """
    r = n_subnets
    if kind == "mesh":
        return tuple((i, j) for i in range(r) for j in range(i + 1, r))
    if kind == "line":
        return tuple((i, i + 1) for i in range(r - 1))
    if kind == "star":
        return tuple((0, i) for i in range(1, r))
    raise ValueError(f"unknown router_kind {kind!r}; known: {ROUTER_KINDS}")


# ---------------------------------------------------------------------------
# Compiled model
# ---------------------------------------------------------------------------


class CompiledNetwork:
    """The runtime network model: precomputed routes and rate tables.

    This is the interface every consumer programs against (the *NetworkModel
    protocol*): ``n``, ``links_for``, ``capacity``, ``latency``, ``subnet``,
    plus the contention constants (``per_flow_cap_mbps``, ``collapse_*``).
    :class:`repro.core.netsim.TestbedSpec` satisfies the same protocol by
    delegating to a compiled default-mesh network, so the fluid simulator
    accepts either interchangeably.
    """

    def __init__(self, spec: NetworkSpec) -> None:
        self.spec = spec
        self.n = spec.n
        self.per_flow_cap_mbps = spec.per_flow_cap_mbps
        self.collapse_gamma = spec.collapse_gamma
        self.collapse_k0 = spec.collapse_k0
        self.collapse_ref_mb = spec.collapse_ref_mb
        # dense node -> subnet table first: an underlay declared with fewer
        # devices than the overlay maps trailing nodes past n_subnets-1
        # (subnet_of is monotone in the node id), and named fabrics extend
        # to cover every mapped router — for the mesh this reproduces the
        # historical TestbedSpec behaviour (extra subnets, direct trunks)
        self.node_subnet = np.array([spec.subnet(u) for u in range(spec.n)],
                                    dtype=np.int64)
        r = max(spec.n_subnets,
                int(self.node_subnet.max(initial=0)) + 1)
        edges = (spec.router_edges if spec.router_edges is not None
                 else router_graph_edges(spec.router_kind, r))
        self.trunk_edges: Tuple[Tuple[int, int], ...] = tuple(sorted(set(edges)))
        self._trunk_index = {e: i for i, e in enumerate(self.trunk_edges)}
        # all-pairs shortest router paths (hop count, low-index tie-break);
        # a fabric that disconnects any subnet pair is rejected here, before
        # the analytic profile builder could silently route around it
        self._paths = _router_paths(r, self.trunk_edges)
        if len(self._paths) != r * r:
            reachable = {d for (s, d) in self._paths if s == 0}
            missing = sorted(set(range(r)) - reachable)
            raise ValueError(
                f"router graph disconnects subnets (e.g. {missing} "
                f"unreachable from 0); every subnet pair needs a route")
        self.access_rate = self._access_rates()
        # per-subnet-pair trunk routes, padded for vectorized gathers:
        # route_trunks[s, d] lists trunk indices (-1 padded), route_hops[s, d]
        # the router-hop count the latency model charges.
        max_len = max((len(p) for p in self._paths.values()), default=0)
        self.route_trunks = -np.ones((r, r, max(max_len, 1)), dtype=np.int64)
        self.route_hops = np.zeros((r, r), dtype=np.int64)
        for (s, d), path in self._paths.items():
            for j, e in enumerate(path):
                self.route_trunks[s, d, j] = self._trunk_index[e]
            # the paper's rule generalized: an intra-subnet transfer pays no
            # router-hop latency; a routed transfer pays one hop per router
            # on the path (trunk count + 1) — for the default full mesh this
            # reproduces the historical 0-or-2 exactly.
            self.route_hops[s, d] = len(path) + 1 if path else 0
        self.latency_table = (spec.base_latency_s
                              + self.route_hops * spec.hop_latency_s)

    def _access_rates(self) -> np.ndarray:
        spec = self.spec
        # cover every referenced physical id (an underlay declared smaller
        # than the overlay maps node ids past phys_n; see node_subnet above)
        phys_n = spec.phys_n or spec.n
        if spec.node_ids is not None:
            phys_n = max(phys_n, max(spec.node_ids) + 1)
        else:
            phys_n = max(phys_n, spec.n)
        if spec.access_range is None:
            phys = np.full(phys_n, spec.access_mbps, dtype=np.float64)
        else:
            lo, hi = spec.access_range
            # one vectorized draw over the full *physical* id range, then
            # index: the rate a device was assigned survives churn masking
            # and sub-sampling because the stream is drawn in id order (a
            # longer draw keeps its prefix)
            phys = np.random.default_rng(spec.het_seed).uniform(lo, hi, phys_n)
        if spec.node_ids is not None:
            return phys[np.asarray(spec.node_ids, dtype=np.int64)]
        return phys[:spec.n]

    # -- NetworkModel protocol ----------------------------------------------
    def subnet(self, node: int) -> int:
        return int(self.node_subnet[node])

    def trunks_between(self, s: int, d: int) -> List[Tuple[int, int]]:
        """The trunk edges a subnet-``s`` -> subnet-``d`` transfer traverses."""
        if s == d:
            return []
        path = self._paths.get((s, d))
        if path is None:
            raise ValueError(f"router graph disconnects subnets {s} and {d}")
        return list(path)

    def links_for(self, src: int, dst: int) -> List[LinkId]:
        s, d = self.subnet(src), self.subnet(dst)
        links: List[LinkId] = [("access-up", src, -1)]
        links.extend(("trunk", a, b) for a, b in self.trunks_between(s, d))
        links.append(("access-down", dst, -1))
        return links

    def capacity(self, link: LinkId) -> float:
        if link[0] == "trunk":
            return self.spec.trunk_mbps
        return float(self.access_rate[link[1]])

    def latency(self, src: int, dst: int) -> float:
        return float(self.latency_table[self.subnet(src), self.subnet(dst)])

    # -- link indexing for the vectorized timing model ----------------------
    @property
    def n_links(self) -> int:
        return 2 * self.n + len(self.trunk_edges)

    def link_capacities(self) -> np.ndarray:
        """Capacity per link index: [access-up x n | access-down x n | trunks]."""
        return np.concatenate([
            self.access_rate, self.access_rate,
            np.full(len(self.trunk_edges), self.spec.trunk_mbps)])

    def link_name(self, idx: int) -> LinkId:
        if idx < self.n:
            return ("access-up", idx, -1)
        if idx < 2 * self.n:
            return ("access-down", idx - self.n, -1)
        a, b = self.trunk_edges[idx - 2 * self.n]
        return ("trunk", a, b)


def _router_paths(
    n_subnets: int, edges: Sequence[Tuple[int, int]]
) -> Dict[Tuple[int, int], List[Tuple[int, int]]]:
    """BFS all-pairs shortest paths over the router graph.

    Returns, per ordered router pair, the list of (normalized) trunk edges
    on the path. Deterministic: BFS visits neighbours in ascending index
    order, so equal-length paths tie-break toward low router ids.
    """
    adj: Dict[int, List[int]] = {r: [] for r in range(n_subnets)}
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    for r in adj:
        adj[r] = sorted(set(adj[r]))
    out: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for s in range(n_subnets):
        prev = {s: -1}
        queue = [s]
        while queue:
            nxt: List[int] = []
            for u in queue:
                for v in adj[u]:
                    if v not in prev:
                        prev[v] = u
                        nxt.append(v)
            queue = nxt
        for d in prev:
            path: List[Tuple[int, int]] = []
            u = d
            while prev[u] != -1:
                path.append((min(u, prev[u]), max(u, prev[u])))
                u = prev[u]
            out[(s, d)] = list(reversed(path))
    return out


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# name -> factory(n) -> NetworkSpec. Every preset is a plain spec, so
# ``ScenarioSpec(underlay="wan")`` and sweep axes over preset names work
# everywhere a spec does.
NETWORK_PRESETS: Dict[str, Callable[[int], NetworkSpec]] = {}


def register_preset(name: str):
    """Decorator: register a ``factory(n) -> NetworkSpec`` under ``name``."""

    def deco(fn: Callable[[int], NetworkSpec]):
        NETWORK_PRESETS[name] = fn
        return fn

    return deco


@register_preset("paper_lan")
def _paper_lan(n: int = 10) -> NetworkSpec:
    """The paper's testbed: 3 subnets behind a full router mesh, uniform
    12 MB/s access, 30 MB/s trunks (the :class:`TestbedSpec` defaults)."""
    return NetworkSpec(name="paper_lan", n=n)


@register_preset("wan")
def _wan(n: int = 10) -> NetworkSpec:
    """A campus-to-campus WAN: 4 sites chained over slow long-haul trunks
    (line fabric — cross-site transfers may traverse several trunks), with
    much higher per-hop latency."""
    return NetworkSpec(
        name="wan", n=n, n_subnets=4, router_kind="line",
        trunk_mbps=8.0, base_latency_s=0.25, hop_latency_s=1.2)


@register_preset("edge")
def _edge(n: int = 10) -> NetworkSpec:
    """Heterogeneous edge deployment: per-device access rates drawn from
    3–16 MB/s (seeded), all sites homed on one hub router (star fabric)."""
    return NetworkSpec(
        name="edge", n=n, n_subnets=4, router_kind="star",
        access_range=(3.0, 16.0), trunk_mbps=20.0, hop_latency_s=0.5)


@register_preset("congested")
def _congested(n: int = 10) -> NetworkSpec:
    """The paper fabric under aggressive goodput collapse: loss-driven
    retransmission sets in at 2 concurrent flows and grows 4x faster."""
    return NetworkSpec(
        name="congested", n=n, collapse_gamma=0.2, collapse_k0=1,
        per_flow_cap_mbps=9.0)


def get_preset(name: str, n: int = 10) -> NetworkSpec:
    """A fresh preset spec sized to ``n`` devices."""
    try:
        factory = NETWORK_PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown network preset {name!r}; known: "
            f"{sorted(NETWORK_PRESETS)}") from None
    return factory(n)


def as_network_model(
    underlay: Union[str, NetworkSpec, "CompiledNetwork", Any],
    n: Optional[int] = None,
):
    """Resolve anything underlay-shaped to a runtime network model.

    Accepts a preset name, a :class:`NetworkSpec` (compiled here), an
    object exposing ``to_network()`` (:class:`repro.core.netsim.
    TestbedSpec` — compiled so hot loops skip its per-call delegation), or
    any object already satisfying the NetworkModel protocol
    (:class:`CompiledNetwork` passes through unchanged).
    """
    if isinstance(underlay, str):
        underlay = get_preset(underlay, n if n is not None else 10)
    if isinstance(underlay, NetworkSpec):
        return underlay.build()
    if hasattr(underlay, "to_network"):
        return underlay.to_network().build()
    if hasattr(underlay, "links_for") and hasattr(underlay, "capacity"):
        return underlay
    raise TypeError(f"not a network model: {underlay!r}")


def as_compiled_network(
    underlay: Union[str, NetworkSpec, "CompiledNetwork", Any],
    n: Optional[int] = None,
) -> "CompiledNetwork":
    """Like :func:`as_network_model` but always a :class:`CompiledNetwork`
    (the vectorized timing model needs the compiled route/rate tables)."""
    model = as_network_model(underlay, n)
    if isinstance(model, CompiledNetwork):
        return model
    raise TypeError(f"cannot compile network model {model!r}")


def _field_tuple(obj) -> Tuple[Any, ...]:
    """A dataclass's field values as a flat tuple (cheap ``astuple`` without
    its deepcopy recursion — all underlay fields are already plain data)."""
    return tuple(getattr(obj, f) for f in obj.__dataclass_fields__)


def underlay_fingerprint(underlay: Union[str, NetworkSpec, Any],
                         n: Optional[int] = None) -> Tuple[Any, ...]:
    """Hashable identity of an underlay declaration (plan-cache key)."""
    if isinstance(underlay, str):
        return ("preset", underlay, n)
    if isinstance(underlay, NetworkSpec):
        return underlay.fingerprint()
    if isinstance(underlay, CompiledNetwork):
        return underlay.spec.fingerprint()
    # dataclass underlays (TestbedSpec) identify by their field values
    if dataclasses.is_dataclass(underlay):
        return (type(underlay).__name__,) + _field_tuple(underlay)
    return ("object", id(underlay))


# ---------------------------------------------------------------------------
# Analytic timing: closed-form per-slot bottleneck + contention
# ---------------------------------------------------------------------------


class TimingContractWarning(UserWarning):
    """The analytic timing estimate is outside its documented tolerance
    contract (DESIGN.md §12): event-driven flooding over a hub-heavy
    overlay, where the effective-concurrency discount misprices the hub's
    access-link burstiness (observed worst case ±38% vs the fluid
    simulator on the 384-cell Barabási–Albert grid)."""


@dataclass
class TimingEstimate:
    """Analytic round-timing results, field-compatible with the fluid
    simulator's :class:`~repro.core.netsim.SimResult` metrics."""

    total_time_s: float
    mean_transfer_s: float
    mean_bandwidth_mbps: float
    n_transfers: int
    max_concurrency: int
    per_slot_s: Optional[np.ndarray] = None
    # set when this estimate is outside the module's tolerance contract
    # (a TimingContractWarning was emitted); None = in contract
    contract_warning: Optional[str] = None


class TimingProfile:
    """The payload-independent timing structure of one (plan, network) pair.

    Construction walks the plan once and aggregates, per slot and per
    traversed physical link: flow count, latency sum and latency max —
    everything the closed-form needs. :meth:`estimate` then evaluates the
    formula for any per-send wire size as pure numpy array work, which is
    what makes whole sweep grids (many payload/codec cells over one plan)
    cost one profile + N vector evaluations instead of N fluid simulations.

    The closed form, per slot, per link ``l`` with ``k`` flows of size
    ``S`` (MB), capacity ``C`` and collapse factor
    ``coll = 1 + gamma_eff * max(0, k_eff - k0)``::

        drain_l = mean_latency_l + k * S / min(C / coll, k * cap)
        floor_l = max_latency_l  + S / min(cap, C)
        T_slot  = max_l max(drain_l, floor_l)

    and the round time is the sum over slots (the self-clocked drain
    barrier). Mean latency — not max — is the first-order-correct offset
    because flows start draining at their own staggered latencies. For
    event-driven policies (flooding) there is no slot barrier: links are
    aggregated over the whole round and the collapse factor is evaluated at
    an effective concurrency ``k_eff = min(0.65 * max adjacent-wave count,
    K)`` — adjacent forwarding waves overlap in flight, while launch ramps
    and early finishers keep the byte-weighted concurrency below the raw
    peak (0.65 reproduces the fluid simulator's byte-weighted average; see
    the module tolerance contract).
    """

    #: event-mode effective-concurrency discount (byte-weighted average
    #: concurrency / peak adjacent-wave concurrency in the fluid simulator)
    EVENT_CONCURRENCY_DISCOUNT = 0.65

    #: hub-heaviness threshold for the out-of-contract warning: per-sender
    #: flow-count skew (busiest access-up link / mean) at or above this
    #: marks the overlay hub-heavy. For flooding the per-sender flow count
    #: is proportional to overlay degree, so this is exactly the degree
    #: skew; 1.5 was calibrated to fire on every shape of the documented
    #: 384-cell Barabási–Albert outlier grid (n ∈ {8, 10, 12, 16} × 6
    #: seeds, m = 2; observed skews 1.54–2.48) while regular families
    #: (Watts–Strogatz ≤ 1.5 boundary-exclusive, complete = 1.0) stay
    #: silent. Genuinely hub-heavy Erdős–Rényi draws also fire — the
    #: warning tracks the structural cause, not the generator's name.
    HUB_SKEW_WARN_THRESHOLD = 1.5

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_policy(cls, policy, network, max_slots: int = 1_000_000
                    ) -> "TimingProfile":
        """Walk a :class:`~repro.core.plan.CommPolicy` once, vectorized —
        no Python send tuples are materialized (the N=1000 sweep path)."""
        network = as_compiled_network(network, n=policy.n)
        builder = _ProfileBuilder(network)
        policy.reset()
        t = 0
        while not policy.done():
            if t >= max_slots:
                raise RuntimeError(f"{policy.kind} did not converge")
            sends = policy.emit(t)
            policy.commit(t, sends)
            builder.add_slot(sends.src, sends.dst)
            t += 1
        return builder.finish(policy.sync)

    @classmethod
    def from_plan(cls, plan, network) -> "TimingProfile":
        """Profile an already-compiled :class:`~repro.core.plan.SlotPlan`."""
        network = as_compiled_network(network, n=plan.n)
        builder = _ProfileBuilder(network)
        for slot in plan.slots:
            arr = np.asarray(slot.sends, dtype=np.int64).reshape(-1, 3)
            builder.add_slot(arr[:, 0], arr[:, 1])
        sync = "event" if plan.kind == "flooding" else "slot"
        return builder.finish(sync)

    # -- evaluation (implemented by the frozen profile) ----------------------
    def estimate(self, size_mb: float) -> TimingEstimate:
        """Closed-form timing for one per-send wire size (MB)."""
        raise NotImplementedError

    def measure_stats(self) -> Dict[str, float]:
        """The :func:`repro.core.plan.measure_policy` counting stats, free —
        the profile walk already counted them, so a consumer needing both
        timing and counts pays for one policy walk, not two."""
        return {"n_slots": self.total_slots,
                "transmissions": self.n_transfers,
                "max_concurrent_sends": self.max_concurrency}


class _ProfileBuilder:
    """Accumulates per-slot link aggregates from vectorized send arrays."""

    def __init__(self, network) -> None:
        self.net = network
        n = network.n
        self.rows: List[Tuple[np.ndarray, ...]] = []
        self.flow_lat: List[np.ndarray] = []
        self.flow_entry: List[np.ndarray] = []  # per-incidence local entry idx
        self.flow_ids: List[np.ndarray] = []  # per-incidence slot-local flow
        self.total_slots = 0  # every emitted slot, empty ones included
        self._subnet = network.node_subnet
        self._lat_table = network.latency_table
        self._route_trunks = network.route_trunks  # (r, r, max_len)
        self._trunk_base = 2 * n

    def add_slot(self, src: np.ndarray, dst: np.ndarray) -> None:
        self.total_slots += 1
        if src.size == 0:
            return
        n = self.net.n
        ssub = self._subnet[src]
        dsub = self._subnet[dst]
        lat = self._lat_table[ssub, dsub]
        # per-flow link incidences: up, down, and the route's trunks
        trunk_rows = self._route_trunks[ssub, dsub]  # (F, max_len)
        tmask = trunk_rows >= 0
        flow_idx = np.arange(src.size)
        inc_flow = np.concatenate([
            flow_idx, flow_idx, np.repeat(flow_idx, tmask.sum(axis=1))])
        inc_link = np.concatenate([
            src, n + dst, self._trunk_base + trunk_rows[tmask]])
        # aggregate to unique (link) rows for this slot
        order = np.argsort(inc_link, kind="stable")
        inc_link_s, inc_flow_s = inc_link[order], inc_flow[order]
        links, first = np.unique(inc_link_s, return_index=True)
        counts = np.diff(np.concatenate((first, [inc_link_s.size])))
        lat_inc = lat[inc_flow_s]
        lat_sum = np.add.reduceat(lat_inc, first)
        lat_max = np.maximum.reduceat(lat_inc, first)
        self.rows.append((links, counts.astype(np.float64), lat_sum, lat_max))
        # per-incidence entry position (into this slot's unique rows), in
        # original incidence order, for the per-flow bottleneck estimate
        entry_of_inc = np.empty(inc_link.size, dtype=np.int64)
        entry_of_inc[order] = np.repeat(
            np.arange(links.size), counts)
        self.flow_entry.append(entry_of_inc)
        self.flow_ids.append(inc_flow)
        self.flow_lat.append(lat)

    def finish(self, sync: str) -> "_FrozenProfile":
        return _FrozenProfile(self.net, sync, self.rows, self.flow_lat,
                              self.flow_entry, self.flow_ids,
                              self.total_slots)


class _FrozenProfile(TimingProfile):
    """The evaluatable profile (all arrays flattened and frozen)."""

    def __init__(self, network, sync, rows, flow_lat, flow_entry, flow_ids,
                 total_slots=None):
        # deliberately *not* calling TimingProfile.__init__ — this is the
        # real layout; the parent class documents the contract
        self.network = network
        self.sync = sync
        self.n_slots = len(rows)  # non-empty slots (the timed ones)
        self.total_slots = len(rows) if total_slots is None else total_slots
        caps = network.link_capacities()
        z64 = np.zeros(0, np.int64)
        zf = np.zeros(0, np.float64)
        self._e_slot = (np.concatenate(
            [np.full(r[0].size, t, np.int64) for t, r in enumerate(rows)])
            if rows else z64)
        self._e_link = np.concatenate([r[0] for r in rows]) if rows else z64
        self._e_count = np.concatenate([r[1] for r in rows]) if rows else zf
        self._e_lat_sum = np.concatenate([r[2] for r in rows]) if rows else zf
        self._e_lat_max = np.concatenate([r[3] for r in rows]) if rows else zf
        self._e_cap = caps[self._e_link] if rows else zf
        self._f_lat = np.concatenate(flow_lat) if flow_lat else zf
        self.n_transfers = int(self._f_lat.size)
        self.max_concurrency = int(max((l.size for l in flow_lat), default=0))
        # global per-incidence (entry, flow) indices
        entry_off = np.cumsum([0] + [r[0].size for r in rows])
        flow_off = np.cumsum([0] + [l.size for l in flow_lat])
        self._i_entry = (np.concatenate(
            [e + entry_off[t] for t, e in enumerate(flow_entry)])
            if flow_entry else z64)
        self._i_flow = (np.concatenate(
            [f + flow_off[t] for t, f in enumerate(flow_ids)])
            if flow_ids else z64)
        # event-mode aggregates: per-link totals + peak adjacent-wave counts
        self._ev_up_skew = 0.0
        if sync == "event" and rows:
            links, inv = np.unique(self._e_link, return_inverse=True)
            K = np.zeros(links.size)
            np.add.at(K, inv, self._e_count)
            lat_sum = np.zeros(links.size)
            np.add.at(lat_sum, inv, self._e_lat_sum)
            lat_max = np.zeros(links.size)
            np.maximum.at(lat_max, inv, self._e_lat_max)
            # per (slot, link) dense counts for adjacent-wave peaks
            dense = np.zeros((self.n_slots, links.size))
            dense[self._e_slot, inv] = self._e_count
            pair = dense + np.vstack((dense[1:], np.zeros((1, links.size))))
            kpair = pair.max(axis=0)
            self._ev_link = links
            self._ev_K = K
            self._ev_lat_mean = lat_sum / K
            self._ev_lat_max = lat_max
            self._ev_kpair = kpair
            self._ev_cap = caps[links]
            # per-sender concentration: flow counts over access-up links
            # (link indices < n by the CompiledNetwork layout) — for
            # flooding this is proportional to overlay degree, the
            # hub-heaviness signal of the tolerance contract
            up = K[links < network.n]
            self._ev_up_skew = float(up.max() / up.mean()) if up.size else 0.0

    # -- the closed form -----------------------------------------------------
    def _collapse(self, k_eff: np.ndarray, size_mb: float) -> np.ndarray:
        net = self.network
        gamma = net.collapse_gamma * (size_mb / net.collapse_ref_mb) ** 0.5
        return 1.0 + gamma * np.maximum(0.0, k_eff - net.collapse_k0)

    def estimate(self, size_mb: float) -> TimingEstimate:
        from .. import obs

        size_mb = float(size_mb)
        net = self.network
        cap = net.per_flow_cap_mbps
        contract_msg: Optional[str] = None
        if self.n_transfers == 0:
            return TimingEstimate(0.0, 0.0, 0.0, 0, 0,
                                  np.zeros(self.n_slots))
        if self.sync == "event":
            coll = self._collapse(
                np.minimum(self.EVENT_CONCURRENCY_DISCOUNT * self._ev_kpair,
                           self._ev_K), size_mb)
            R = np.minimum(self._ev_cap / coll, self._ev_K * cap)
            drain = self._ev_lat_mean + self._ev_K * size_mb / R
            floor = self._ev_lat_max + size_mb / np.minimum(cap, self._ev_cap)
            total = float(np.maximum(drain, floor).max())
            per_slot = None
            if self._ev_up_skew > self.HUB_SKEW_WARN_THRESHOLD:
                contract_msg = (
                    f"event-driven timing estimate on a hub-heavy overlay: "
                    f"per-sender access-link skew {self._ev_up_skew:.2f} > "
                    f"{self.HUB_SKEW_WARN_THRESHOLD} is outside the +/-15% "
                    f"accuracy contract (DESIGN.md §12; worst observed "
                    f"deviation ±38% on the barabasi_albert outlier "
                    f"grid) — treat total_time_s as a lower-confidence "
                    f"ordering signal, or use the async event engine")
                warnings.warn(contract_msg, TimingContractWarning,
                              stacklevel=3)
                rec = obs.get()
                if rec.enabled:
                    rec.count("timing.contract_warnings")
                    rec.gauge("timing.hub_skew", self._ev_up_skew)
        else:
            k = self._e_count
            coll = self._collapse(k, size_mb)
            R = np.minimum(self._e_cap / coll, k * cap)
            drain = self._e_lat_sum / k + k * size_mb / R
            floor = self._e_lat_max + size_mb / np.minimum(cap, self._e_cap)
            per_entry = np.maximum(drain, floor)
            per_slot = np.zeros(self.n_slots)
            np.maximum.at(per_slot, self._e_slot, per_entry)
            total = float(per_slot.sum())
        # per-flow bottleneck estimate (initial fair share, capped)
        k = self._e_count
        share = (self._e_cap / self._collapse(k, size_mb)) / k
        flow_rate = np.full(self.n_transfers, np.inf)
        np.minimum.at(flow_rate, self._i_flow, share[self._i_entry])
        flow_rate = np.minimum(flow_rate, cap)
        dur = self._f_lat + size_mb / flow_rate
        return TimingEstimate(
            total_time_s=total,
            mean_transfer_s=float(dur.mean()),
            mean_bandwidth_mbps=float((size_mb / dur).mean()),
            n_transfers=self.n_transfers,
            max_concurrency=self.max_concurrency,
            per_slot_s=per_slot,
            contract_warning=contract_msg)


def estimate_timing(plan, network, bytes_per_payload: float) -> TimingEstimate:
    """Analytic round timing of a communication plan on a network model.

    ``plan`` is a compiled :class:`~repro.core.plan.SlotPlan` or a live
    :class:`~repro.core.plan.CommPolicy`; ``network`` anything
    :func:`as_network_model` accepts (preset name, :class:`NetworkSpec`,
    :class:`CompiledNetwork`, :class:`~repro.core.netsim.TestbedSpec`);
    ``bytes_per_payload`` the wire bytes of one send (codec-encoded,
    ``payload_fraction`` applied — i.e. exactly what the fluid simulator
    moves per flow). Reuse a :class:`TimingProfile` directly when sweeping
    many payload sizes over one plan.
    """
    from .plan import CommPolicy  # local: plan does not import network

    if isinstance(plan, CommPolicy):
        profile = TimingProfile.from_policy(plan, network)
    else:
        profile = TimingProfile.from_plan(plan, network)
    return profile.estimate(bytes_per_payload / 1e6)


# ---------------------------------------------------------------------------
# Steady-state throughput (the event engine's analytic contract)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ThroughputEstimate:
    """Analytic steady-state throughput of an asynchronously-pipelined plan.

    ``fill_latency_s`` is the pipeline-fill time: admission of round 0 to
    its completion (one full round through the store-and-forward underlay,
    mean compute included). ``steady_period_s`` is the predicted
    inter-round completion gap once the ``max_staleness + 1``-deep pipeline
    is full; ``rounds_per_s`` its reciprocal. The two structural bounds the
    period is derived from are exposed for inspection: the busiest link's
    serialized per-round demand and the slowest node's serial span.
    """

    rounds_per_s: float
    steady_period_s: float
    fill_latency_s: float
    bottleneck_busy_s: float  # max over links of Σ size/capacity per round
    node_span_s: float  # max over nodes of compute + own-clock round work


def estimate_throughput(plan, network, bytes_per_payload: float,
                        max_staleness: int = 0,
                        compute_time_s: float = 0.0,
                        compute_jitter_s: float = 0.0) -> ThroughputEstimate:
    """Steady-state rounds/sec of a plan pipelined on the event engine.

    Same calling convention as :func:`estimate_timing` (``plan`` is a live
    policy or compiled plan, ``bytes_per_payload`` the wire bytes of one
    send), plus the async knobs of the event executor. The form walks
    *one* round through the discrete-event link model (the pipeline fill),
    then takes the steady-state period as the binding structural bound:

    * ``max_staleness = 0`` — the barrier: every round repeats the fill,
      so the period *is* the single-round makespan;
    * ``max_staleness >= 1`` — rounds overlap; the period is bounded below
      by the busiest link's per-round serialized demand, the slowest
      node's serial span (a node's rounds chain on its own clock), and the
      admission window ``fill / (max_staleness + 1)`` — the max of the
      three is the estimate.

    Compute jitter enters at its expectation (``jitter / 2``); the
    contract against multi-round engine runs is the same ±15% the timing
    model carries against the fluid simulator (enforced by
    ``benchmarks/async_bench.py`` and ``tests/test_events.py``).
    """
    from .events import AsyncEventEngine, plan_slots  # local: engine layer

    net = as_compiled_network(network, n=plan.n)
    slots = plan_slots(plan)
    size_mb = bytes_per_payload / 1e6
    n = net.n
    compute = np.full(n, compute_time_s + compute_jitter_s / 2.0)
    eng = AsyncEventEngine()
    eng.add_round(range(n), net, slots, size_mb, compute)
    (rt,) = eng.run()
    fill = rt.completed_s
    link_busy = max(eng.link_busy.values(), default=0.0)
    span = float(eng.node_spans(0).max()) if n else 0.0
    if max_staleness <= 0:
        period = fill
    else:
        period = max(link_busy, span, fill / (max_staleness + 1))
    return ThroughputEstimate(
        rounds_per_s=(1.0 / period if period > 0 else float("inf")),
        steady_period_s=period, fill_latency_s=fill,
        bottleneck_busy_s=link_busy, node_span_s=span)


# ---------------------------------------------------------------------------
# Network-aware slot length (paper III-C, on the physical model)
# ---------------------------------------------------------------------------


def slot_length_for_network(
    g: Graph, colors: np.ndarray, network, model_size_mb: float
) -> float:
    """The moderator's slot length derived from the network model.

    The paper's formula extrapolates a ping measurement to the model size;
    with a declared underlay the moderator can do better: the slot must
    cover the slowest same-colored multicast, which the analytic model
    gives directly — max over colors of the bottleneck slot time when that
    color's nodes each send to all their schedule neighbours.
    """
    from .plan import MstExchangePolicy  # local: avoid import cycle

    net = as_compiled_network(network, n=g.n)
    profile = TimingProfile.from_policy(
        MstExchangePolicy(g, np.asarray(colors)), net)
    est = profile.estimate(model_size_mb)
    if est.per_slot_s is None or est.per_slot_s.size == 0:
        return 0.0
    return float(est.per_slot_s.max())
