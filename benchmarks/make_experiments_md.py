"""Assemble EXPERIMENTS.md data sections from experiments/*.json.

  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import glob
import json
import os

from .paper_tables import markdown_tables, simulate_all
from .roofline_report import load_results, markdown_table

HEADER = """# EXPERIMENTS

All results reproducible with:

```bash
export PYTHONPATH=src
python -m repro.launch.dryrun --all [--multi-pod]   # §Dry-run, §Roofline
python -m benchmarks.run                            # §Paper-tables + CSV
python -m benchmarks.hillclimb                      # §Perf variants
python -m benchmarks.make_experiments_md            # regenerate this file
pytest tests/                                       # invariants behind all claims
```

Hardware model (target): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip, 16 GiB HBM. This container is CPU-only: the
dry-run lowers + compiles for a 512-placeholder-device host platform, so all
terms are *derived from the compiled artifact*, not measured wall time.
"""

PAPER_SECTION = """
---

## §Paper-tables — reproduction of the paper's claims (Tables III–V)

Testbed simulator (`repro.core.netsim`): 10 nodes, 3 router subnets,
fair-share fluid flows with congestion goodput collapse, FTP setup latency.
Broadcast = all N·(N−1) transfers at once on the complete overlay (hence one
merged broadcast column in the paper); MOSGU = the 2-colored MST exchange.
{tables}

**Claim validation** (asserted in `tests/test_netsim.py`):

| claim (paper) | paper value | this reproduction |
|---|---|---|
| effective bandwidth gain | 2.2× – 8.01× | {gmin:.2f}× – {gmax:.2f}× |
| round-time speedup | up to 4.38× | {smin:.2f}× – {smax:.2f}× |
| gains grow with model size | ✓ (§V-A) | ✓ mean gain v3s {g_small:.2f}× → b3 {g_large:.2f}× |
| complete topology best bandwidth | ✓ (§V-B) | ✓ (asserted) |
| broadcast bandwidth magnitude | 0.767–1.785 MB/s | {bmin:.2f}–{bmax:.2f} MB/s |
| broadcast is topology-independent | merged table cells | exact (complete overlay) |

Structural claims (exact, `tests/test_gossip.py` + `examples/topology_playground.py`):

- MST dissemination uses **exactly N(N−1) transmissions** (the paper's
  redundancy removal): 90 at N=10 vs 340–900 for flooding (3.8–10×); at the
  TPU-mesh N=32: 992 vs 3 904–31 744 (3.9–32×).
- Within any slot only one color transmits; senders and receivers are
  disjoint — the paper's contention-freedom, verified on every compiled plan.
- The compiled static plan reproduces the live FIFO queue engine
  **slot-for-slot** (Table I semantics), including the degree-1 rule, FIFO
  order, and drop/retransmission behaviour.
- Prim/Kruskal/Borůvka agree on MST weight (property-tested); BFS 2-colors
  every MST (paper III-C).
"""

DRYRUN_SECTION = """
---

## §Dry-run — 10 architectures × 4 shapes × {{16×16, 2×16×16}}

**{n_ok} ok + {n_skip} documented skips = {n_total} pairs.** Every pair
lowers AND compiles under GSPMD with the DESIGN.md §4 sharding recipe.
Skips: whisper-tiny × long_500k (×2 meshes) — a 524k sliding-window decoder
on a 448-position encoder-decoder has no modelling meaning (DESIGN.md
§Arch-applicability). Training shapes lower the full DFL step (local grad
step + optimizer + MOSGU gossip); decode shapes lower `serve_step` (1 token
vs a seq_len KV/SSM cache); prefill lowers the forward pass. Raw artifacts
with memory_analysis, collective censuses and gossip plans:
`experiments/dryrun/*.json`.

Gossip schedule at production scale (32 nodes multi-pod / 16 single-pod,
nodes = 16-chip replica groups; MoE archs gossip over the pod axis with the
data axis used for expert parallelism):

| mode | transmissions/round (N=32) | bytes on wire (smollm, bf16) |
|---|---|---|
| dissemination (paper-faithful) | 992 = N(N−1) | 674 GB |
| flooding broadcast (baseline) | 31 744 on complete overlay | 21.6 TB |
| tree all-reduce (beyond-paper) | 62 = 2(N−1) | 42 GB |
| 1-hop mixing (beyond-paper) | 62 | 42 GB |

**HBM fit.** `memory_analysis()` peaks on the CPU dry-run inflate bf16
intermediates ≈2× (XLA CPU legalizes bf16 dots via f32 converts — verified
in buffer-assignment dumps), so `peak GiB` below is an upper bound on the
TPU peak. All 38 decode/prefill rows fit < 16 GiB outright. Train rows:
smollm 4.2, whisper 4.1, granite 9.3, gemma2/paligemma ≈ 11–13, falcon-mamba
18.3, qwen3 22.5, zamba2 23.9, stablelm 30.6, arctic 76 (measured upper
bounds; ≈½ on TPU). The §Perf hillclimbs bring the over-budget archs down
(e.g. stablelm −24%, arctic-with-padded-heads) and DESIGN.md records the
per-arch optimizer/microbatching levers used.
"""

ROOFLINE_SECTION = """
---

## §Roofline — all 80 (arch × shape × mesh) baselines

compute = HLO_FLOPs/(chips·197e12) · memory = HLO_bytes/(chips·819e9) ·
collective = wire_bytes/(chips·50e9), all per-step seconds (ms shown).
FLOPs/bytes from the trip-count-aware HLO analyzer
(`launch/hlo_analysis.py`) — XLA's `cost_analysis()` counts while bodies
once; ours multiplies trip counts back (validated exactly on matmul/scan
calibration tests; all-reduce weighted 2× for its two wire phases).
useful-FLOPs ratio = MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6·N_active·D
(train) or 2·N_active·D (prefill/decode); > 1 means the compiler saw fewer
FLOPs than the analytic model (fusion/elision), ≪ 1 means redundant compute
(replication, remat, capacity padding).

{table}

**Reading the table** (per-arch dominant bottleneck, single-pod train):

- **collective-bound**: qwen3 (expert all-to-all + TP), stablelm
  (fp32-master TP reductions + seq-parallel gathers — NOT gossip: the MOSGU
  round is 0.25% of its wire bytes, see §Perf), smollm (replicated-head
  era; fixed by padding in §Perf).
- **memory-bound**: all SSM/hybrid archs — the associative-scan level
  buffers dominate HBM traffic (the selective-scan Pallas kernel removes
  them; quantified in §Perf via the sequential-scan variant), plus every
  prefill_32k (f32 score blocks at 32k).
- **decode shapes** are uniformly memory-bound (cache streaming), matching
  the standard serving roofline; long_500k rows are tiny for SSM/hybrid
  (state-only) and windowed-dense — the sub-quadratic requirement holds.
- **multi-pod vs single-pod**: per-chip terms roughly halve at fixed global
  batch (2× chips), while the gossip schedule grows from 16 to 32 nodes with
  exactly one DCN edge in the MST — the paper's subnet structure reproduced
  on pods.
"""


def _perf_section() -> str:
    out = ["\n---\n\n## §Perf — paper-faithful baseline, then beyond-paper hillclimbs\n"]
    out.append("""
Methodology: per pair, napkin-math hypotheses over the dominant roofline
term → implement → re-lower + re-compile → extract terms → confirm/refute.
Three pairs selected per the brief (worst fraction / most collective-bound /
most representative) plus a bonus SSM pair. Raw: `experiments/perf/*.json`.
""")
    descr = {
        "smollm": (
            "smollm-360m × train_4k × 16×16 — most representative of the "
            "technique (a full MOSGU gossip round every step) and worst "
            "useful-FLOPs fraction"),
        "stablelm": (
            "stablelm-12b × train_4k × 16×16 — worst absolute roofline terms, "
            "collective-bound"),
        "arctic": (
            "arctic-480b × train_4k × 2×16×16 — most collective-bound "
            "(expert-parallel all-to-all + inter-pod gossip over DCN)"),
        "zamba2": (
            "zamba2-7b × train_4k × 16×16 (bonus) — memory-bound SSM scan"),
    }
    for name in ("smollm", "stablelm", "arctic", "zamba2"):
        path = f"experiments/perf/{name}.json"
        if not os.path.exists(path):
            continue
        rows = json.load(open(path))
        out.append(f"\n### {descr.get(name, name)}\n")
        out.append("| variant | compute | memory | collective | peak GiB | useful |")
        out.append("|---|---|---|---|---|---|")
        for r in rows:
            if r.get("status") != "ok":
                out.append(f"| {r['variant']} | error | | | | |")
                continue
            out.append(
                f"| {r['variant']} | {r['compute_s']*1e3:.0f} ms "
                f"| {r['memory_s']*1e3:.0f} ms | {r['collective_s']*1e3:.0f} ms "
                f"| {r['peak_memory_gb']:.1f} | {min(r['useful_flops_ratio'],99):.2f} |")
        out.append("")
    return "\n".join(out)


def main() -> None:
    res = simulate_all()
    gains, speeds, bws = [], [], []
    from .paper_tables import CODES, TOPOLOGIES

    for (t, c), r in res.items():
        gains.append(r["mosgu"].mean_bandwidth_mbps / r["broadcast"].mean_bandwidth_mbps)
        speeds.append(r["broadcast"].total_time_s / r["mosgu"].total_time_s)
        bws.append(r["broadcast"].mean_bandwidth_mbps)
    g_small = sum(res[(t, "v3s")]["mosgu"].mean_bandwidth_mbps /
                  res[(t, "v3s")]["broadcast"].mean_bandwidth_mbps
                  for t in TOPOLOGIES) / 4
    g_large = sum(res[(t, "b3")]["mosgu"].mean_bandwidth_mbps /
                  res[(t, "b3")]["broadcast"].mean_bandwidth_mbps
                  for t in TOPOLOGIES) / 4

    results = load_results()
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)

    doc = HEADER
    doc += PAPER_SECTION.format(
        tables=markdown_tables(res),
        gmin=min(gains), gmax=max(gains), smin=min(speeds), smax=max(speeds),
        g_small=g_small, g_large=g_large, bmin=min(bws), bmax=max(bws),
    )
    doc += DRYRUN_SECTION.format(n_ok=n_ok, n_skip=n_skip, n_total=len(results))
    doc += ROOFLINE_SECTION.format(table=markdown_table())
    doc += _perf_section()
    doc += _PERF_NARRATIVE
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print(f"EXPERIMENTS.md written: {n_ok} ok / {n_skip} skipped dry-runs, "
          f"{len(glob.glob('experiments/perf/*.json'))} hillclimb files")


_PERF_NARRATIVE = """
### Hillclimb log (hypothesis → change → measurement → verdict)

**smollm-360m × train_4k** (dominant: memory, then collective)

1. *Paper-faithful baseline first*: `dissemination` gossip — every node ends
   the round with all 16 models (N-slot buffers, 240 ppermute payloads).
   vs `tree_allreduce`: memory 12.48→10.52 s, peak 11.7→5.9 GiB, collective
   2.88→2.47 s, **identical FedAvg model to the bit** (tested). The
   beyond-paper schedule is a free win, exactly as DESIGN.md §6 predicts
   (O(N)→O(1) buffers, N(N−1)→2(N−1) transmissions). **Confirmed.**
2. *Hypothesis*: 15 attention heads don't divide the 16-way model axis →
   attention runs replicated on every chip; at s=4096 the replicated score
   work is ~11× the useful per-chip FLOPs. Padding to 16 heads (kv 5→8)
   should cut compute ≈2× and memory ≈3×. *Measured*: compute 265→112 ms
   (−58%), memory 10.52→3.36 s (−68%), useful-FLOPs 0.17→0.40, peak
   5.9→4.7 GiB. **Confirmed** — biggest single win; costs +6.7% dead
   parameters.
3. *Hypothesis*: gossip (f32 master over ~36 permute steps) dominates the
   remaining 2.98 s collective term; bf16 wire should halve it. *Measured*:
   no change. The collective census shows the MOSGU round is ~65 ms of the
   term — TP collectives dominate. **Refuted**, and the refutation is the
   headline: at pod scale the paper's schedule is already so cheap that
   intra-node parallelism traffic, not gossip, is the wall. (bf16 wire is
   real at the jaxpr level — bf16 ppermutes are emitted — but XLA's *CPU*
   backend folds the converts back into f32; on the TPU backend the wire
   stays bf16. Analytically it halves gossip bytes: 42→21 GB/round.)

**stablelm-12b × train_4k** (dominant: collective 21.1 s)

1. *Hypothesis*: fp32-master gossip dominates → bf16 wire halves the term.
   *Measured*: unchanged — gossip is ~2.5 GiB of the 984 GiB/device wire
   traffic (0.25%). **Refuted** (same lesson as smollm at 32× the size).
2. *Hypothesis*: dropping the fp32 master (bf16-moment Adam) removes the
   46 GB gossip payload and ~3 GiB/chip of state. *Measured*: peak
   30.6→27.3 GiB; terms unchanged (it was state, not traffic).
   **Confirmed for fit.**
3. *Hypothesis*: 4-way microbatching halves activation peaks. *Measured*:
   peak 27.3→24.7 GiB but memory +24% / collective +47% (per-microbatch
   gathers do not amortize). **Confirmed for fit, with a quantified
   traffic cost** — microbatching is a fit lever, not a perf lever.
4. *Hypothesis*: the 2 368 weighted all-gathers are seq-parallel re-gathers;
   disabling sequence parallelism should slash the collective term.
   *Measured*: collective only 21.1→20.4 s (−3.5%) while memory +139% and
   peak 30.6→91.9 GiB. **Refuted** — the gathers are intrinsic Megatron-TP
   reshards, and seq-parallel is nearly free collective-wise while saving
   3× memory. Kept ON everywhere. Identified next lever: fused
   gather-matmul kernels.

**arctic-480b × train_4k × 2×16×16** (dominant: memory 87 s, collective 60 s)

1. *Hypothesis*: bf16 wire halves gossip. *Measured*: no-op — params are
   already bf16 and the 2-node pod-level gossip is ~76 ms of the 60 s term;
   EP all-to-all + TP dominates. **Refuted** (consistent with the others).
2. *Hypothesis*: capacity factor 1.25→1.0 cuts expert dispatch payloads 20%.
   *Measured*: collective 60.0→55.1 s (−8.2%), compute −7.8%. **Confirmed
   in direction at half the predicted size** (TP traffic dilutes the
   all-to-all share).
3. *Hypothesis*: 56 heads replicate attention (56 % 16 ≠ 0); padding to 64
   shards 4 heads/chip and removes the replicated (b, 56, q, k) f32 scores.
   *Measured*: peak **76.2→34.4 GiB (−55%)**, memory 87.2→46.5 s (−47%),
   compute −22%, collective −10%. **Confirmed** — with the CPU→TPU ≈2×
   memory inflation this brings arctic inside the 16 GiB budget.
4. *Hypothesis*: halving microbatches 8→4 halves per-step parameter
   re-reads (the 480B weights stream from HBM once per microbatch) at ~2×
   activation peak. *Measured*: memory 42.4→34.5 s (−19%), collective
   49.2→40.5 s (−18%), peak 34.1→36.4 GiB (+7%). **Confirmed** — and the
   bottleneck flips to collective, so the next iteration would target the
   EP all-to-all again (stop criterion not yet reached).
5. Combined recipe (pad-64 + cf 1.0 + mb 4): compute 1.75 s / memory 34.5 s
   / collective 40.5 s / peak 36.4 GiB — the recommended production config
   (vs 2.51 / 87.2 / 60.0 / 76.2 baseline: **−30% / −60% / −33% / −52%**).

**zamba2-7b × train_4k** (bonus; dominant: memory 200 s)

1. *Hypothesis*: `associative_scan` materializes ~2·log2(chunk) full-chunk
   (b, c, h, hd, n) f32 level buffers per chunk; replacing it with a
   sequential in-chunk scan (the Pallas kernel's dataflow) should cut HBM
   traffic ~5–10×. *Measured*: memory term went **UP 5×** (200→986 s).
   **Refuted, instructively**: in pure XLA each sequential step round-trips
   the (b, h, hd, n) state and its operands through HBM — there is no way
   to express "state stays in VMEM across steps" at the HLO level; the
   associative form amortizes via large fused level passes and is the right
   *XLA* lowering. The ~150× traffic win (napkin: per-layer ≈0.5 GB of
   in/out streams vs ≈84 GB of level buffers) is available **only** to the
   Pallas kernel (`kernels/scan/mamba_scan.py`, validated bit-exact against
   the oracle) — this measurement is the quantified case for shipping it.
2. bf16 wire: unchanged (gossip ≪ TP traffic), consistent with all pairs.

### Summary

- **Paper-faithful reproduction**: dissemination gossip lowers, compiles and
  trains end-to-end (examples/train_dfl.py: 4 non-IID silos, 13.6M-param
  model, 150 steps, loss 9.08→5.01 with a full MOSGU round per step —
  `experiments/training/train_dfl_150steps.log`), matches the queue engine
  slot-for-slot, and its FedAvg equals the beyond-paper tree schedule
  bit-for-bit. Paper-faithful and optimized baselines recorded separately.
- **Beyond-paper wins**: tree all-reduce on the colored MST (16× fewer
  transmissions, O(1) buffers); head padding (smollm: −58% compute, −68%
  memory; arctic: −55% peak, −47% memory); capacity-1.0 routing (−8% wire);
  Adafactor + microbatching (a 480B DFL replica fits a 256-chip node);
  sequence-parallel activations (falcon-mamba 105→17 GiB, enabled for all
  baselines); bf16 gossip wire (2× gossip bytes, analytic).
- **Main lesson vs the paper**: on a TPU fabric the MOSGU schedule is so
  efficient that decentralized training becomes bound by *intra-node*
  parallelism traffic — the opposite regime from the paper's router
  testbed, where inter-node gossip was the bottleneck. The technique
  transfers; the bottleneck moves. Three of four "optimize the gossip
  further" hypotheses were refuted by measurement, which is precisely the
  paper-to-production gap this framework exists to expose.
"""


if __name__ == "__main__":
    main()
